"""Minimum DFS code canonicalization + pattern index + induced subgraphs."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared test dep; deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.induced import (induced_edge_ids, induced_edge_ids_semijoin,
                                induced_subgraph, pattern_to_query)
from repro.core.pattern import (Pattern, PatternIndex, min_dfs_code,
                                pattern_of)
from repro.core.placement import (DynamicPlacement, PatternProfile,
                                  greedy_knapsack)
from repro.rdf.dictionary import Dictionary
from repro.rdf.graph import TripleStore
from repro.sparql.matcher import match_bgp
from repro.sparql.query import QueryGraph, TriplePattern


def permute(edges, n, perm):
    return tuple(sorted((perm[u], perm[v], l) for (u, v, l) in edges))


def all_perms(n):
    import itertools
    return list(itertools.permutations(range(n)))


# -- canonical code properties ----------------------------------------------

CASES = [
    # (edges, n_vertices)
    (((0, 1, 5),), 2),                                   # single edge
    (((0, 0, 3),), 1),                                   # self loop
    (((0, 1, 1), (1, 2, 1)), 3),                         # chain same label
    (((0, 1, 1), (1, 2, 2)), 3),                         # chain diff labels
    (((0, 1, 1), (0, 2, 1), (0, 3, 1)), 4),              # star
    (((0, 1, 1), (1, 2, 1), (2, 0, 1)), 3),              # directed 3-cycle
    (((0, 1, 1), (1, 0, 1)), 2),                         # 2-cycle
    (((0, 1, 1), (0, 1, 2)), 2),                         # parallel edges
    (((0, 1, 1), (1, 2, 2), (2, 3, 3), (3, 0, 4)), 4),   # 4-cycle labeled
    (((0, 1, 1), (1, 2, 1), (0, 2, 1), (2, 3, 2)), 4),   # triangle + tail
]


@pytest.mark.parametrize("edges,n", CASES)
def test_code_permutation_invariant(edges, n):
    base = min_dfs_code(edges, n)
    for perm in all_perms(n):
        assert min_dfs_code(permute(edges, n, perm), n) == base


def test_direction_matters():
    chain = min_dfs_code(((0, 1, 1), (1, 2, 1)), 3)      # a->b->c
    inv = min_dfs_code(((0, 1, 1), (2, 1, 1)), 3)        # a->b<-c
    assert chain != inv


def test_labels_matter():
    c1 = min_dfs_code(((0, 1, 1), (1, 2, 2)), 3)
    c2 = min_dfs_code(((0, 1, 2), (1, 2, 1)), 3)
    assert c1 != c2


def test_nonisomorphic_same_degrees():
    # two graphs, same degree sequence, different structure:
    # 6-cycle vs two 3-cycles are not weakly-connected comparable; use
    # directed: path+backedge variants
    g1 = ((0, 1, 1), (1, 2, 1), (2, 3, 1), (3, 0, 1))    # 4-cycle
    g2 = ((0, 1, 1), (1, 0, 1), (2, 3, 1), (3, 2, 1))    # not connected
    with pytest.raises(ValueError):
        min_dfs_code(g2, 4)
    assert min_dfs_code(g1, 4)


@st.composite
def random_pattern(draw):
    n = draw(st.integers(2, 5))
    n_extra = draw(st.integers(0, 4))
    # build a random connected graph: spanning tree + extra edges
    edges = set()
    for v in range(1, n):
        u = draw(st.integers(0, v - 1))
        if draw(st.booleans()):
            u, v2 = u, v
        else:
            u, v2 = v, u
        edges.add((u, v2, draw(st.integers(0, 2))))
    for _ in range(n_extra):
        u = draw(st.integers(0, n - 1))
        v = draw(st.integers(0, n - 1))
        edges.add((u, v, draw(st.integers(0, 2))))
    return tuple(sorted(edges)), n


@given(random_pattern(), st.randoms())
@settings(max_examples=80, deadline=None)
def test_code_invariance_random(pat, rnd):
    edges, n = pat
    base = min_dfs_code(edges, n)
    perm = list(range(n))
    rnd.shuffle(perm)
    assert min_dfs_code(permute(edges, n, perm), n) == base


@given(random_pattern(), random_pattern())
@settings(max_examples=60, deadline=None)
def test_code_distinguishes(pat_a, pat_b):
    """Equal codes -> actually isomorphic (verified by brute force)."""
    ea, na = pat_a
    eb, nb = pat_b
    ca, cb = min_dfs_code(ea, na), min_dfs_code(eb, nb)
    if (na, ca) == (nb, cb):
        iso = any(permute(ea, na, perm) == tuple(sorted(eb))
                  for perm in all_perms(na))
        assert iso, f"collision: {ea} vs {eb}"


# -- pattern extraction -------------------------------------------------------

def test_pattern_of_merges_constants():
    # <a> k ?y . <a> l ?z -> constant 'a' is one vertex
    q = QueryGraph([TriplePattern(7, 0, "?y"), TriplePattern(7, 1, "?z")], [])
    p = pattern_of(q)
    assert p.n_vertices == 3 and p.n_edges == 2
    # isomorphic query with different constant
    q2 = QueryGraph([TriplePattern(9, 0, "?a"), TriplePattern(9, 1, "?b")], [])
    assert pattern_of(q2).isomorphic_to(p)
    # different structure: two separate subjects would not be connected
    q3 = QueryGraph([TriplePattern("?x", 0, "?y"),
                     TriplePattern("?x", 1, "?z")], [])
    assert pattern_of(q3).isomorphic_to(p)


def test_pattern_index_roundtrip():
    idx = PatternIndex()
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?y", 1, "?z")], [])
    p = pattern_of(q)
    idx.add(p, "ES1")
    # same shape, renamed vars + a constant
    q2 = QueryGraph([TriplePattern(3, 0, "?b"), TriplePattern("?b", 1, "?c")],
                    [])
    assert idx.lookup_query(q2) == ["ES1"]
    # different predicate -> miss
    q3 = QueryGraph([TriplePattern("?x", 1, "?y"),
                     TriplePattern("?y", 0, "?z")], [])
    assert idx.lookup_query(q3) == []


def test_shared_predicate_variable_not_indexable():
    q = QueryGraph([TriplePattern("?x", "?p", "?y"),
                    TriplePattern("?y", "?p", "?z")], [])
    p = pattern_of(q)
    assert not p.indexable
    idx = PatternIndex()
    with pytest.raises(ValueError):
        idx.add(p, "x")
    assert idx.lookup(p) == []


# -- induced subgraphs ---------------------------------------------------------

def star_store():
    d = Dictionary()
    for i in range(10):
        d.add_entity(f"e{i}")
    k = d.add_predicate("k")
    l = d.add_predicate("l")
    # e0 -k-> e1..e3 ; e1 -l-> e4 ; e5 -k-> e6 (no l continuation)
    s = np.array([0, 0, 0, 1, 5])
    p = np.array([k, k, k, l, k])
    o = np.array([1, 2, 3, 4, 6])
    return TripleStore(s, p, o, d.num_entities, d.num_predicates), d, (k, l)


def test_induced_exact_chain():
    store, d, (k, l) = star_store()
    # pattern ?a -k-> ?b -l-> ?c : only e0->e1->e4 participates
    q = QueryGraph([TriplePattern("?a", k, "?b"),
                    TriplePattern("?b", l, "?c")], [])
    p = pattern_of(q)
    eids = induced_edge_ids(store, [p])
    sub = store.subgraph(eids)
    assert sub.num_triples == 2
    # completeness: every match of an isomorphic query over G is in G[P]
    res_g = match_bgp(store, q)
    res_sub = match_bgp(sub, q)
    assert res_g.num_matches == res_sub.num_matches == 1


def test_semijoin_superset_and_acyclic_exact():
    store, d, (k, l) = star_store()
    q = QueryGraph([TriplePattern("?a", k, "?b"),
                    TriplePattern("?b", l, "?c")], [])
    p = pattern_of(q)
    exact = set(induced_edge_ids(store, [p]).tolist())
    semi = set(induced_edge_ids_semijoin(store, [p]).tolist())
    assert exact <= semi
    assert exact == semi  # acyclic pattern -> full reducer is exact


@st.composite
def random_store_and_query(draw):
    n_ent = draw(st.integers(3, 7))
    n_pred = draw(st.integers(1, 3))
    n_trip = draw(st.integers(2, 14))
    s = draw(st.lists(st.integers(0, n_ent - 1), min_size=n_trip,
                      max_size=n_trip))
    p = draw(st.lists(st.integers(0, n_pred - 1), min_size=n_trip,
                      max_size=n_trip))
    o = draw(st.lists(st.integers(0, n_ent - 1), min_size=n_trip,
                      max_size=n_trip))
    # connected random query (2-3 patterns)
    npat = draw(st.integers(1, 3))
    vars_ = ["?a", "?b", "?c", "?d"]
    pats = [TriplePattern("?a", draw(st.integers(0, n_pred - 1)), "?b")]
    used = ["?a", "?b"]
    for i in range(1, npat):
        anchor = draw(st.sampled_from(used))
        nv = vars_[len(used)] if len(used) < len(vars_) else "?a"
        if draw(st.booleans()):
            pats.append(TriplePattern(anchor,
                                      draw(st.integers(0, n_pred - 1)), nv))
        else:
            pats.append(TriplePattern(nv, draw(st.integers(0, n_pred - 1)),
                                      anchor))
        if nv not in used:
            used.append(nv)
    return (np.array(s), np.array(p), np.array(o), n_ent, n_pred,
            QueryGraph(pats, []))


@given(random_store_and_query())
@settings(max_examples=40, deadline=None)
def test_induced_completeness_property(case):
    """Paper's core guarantee: matches of q over G == matches over G[P] when
    q is isomorphic to a stored pattern p (here p = pattern_of(q))."""
    s, p, o, ne, npred, q = case
    store = TripleStore(s, p, o, ne, npred)
    pat = pattern_of(q)
    sub = induced_subgraph(store, [pat], method="exact")
    rg = match_bgp(store, q)
    rs = match_bgp(sub, q)
    def rows(res):
        if not res.var_names:
            return {()} if res.num_matches else set()
        orderv = sorted(res.var_names)
        idx = [res.var_names.index(v) for v in orderv]
        return {tuple(r[idx]) for r in res.bindings}
    assert rows(rg) == rows(rs)
    # semijoin superset never loses matches either
    sub2 = induced_subgraph(store, [pat], method="semijoin")
    rs2 = match_bgp(sub2, q)
    assert rows(rg) == rows(rs2)


# -- placement -----------------------------------------------------------------

def test_greedy_knapsack_prefers_ratio():
    profs = [
        PatternProfile(None, frequency=100, size_bytes=100),   # ratio 1.0
        PatternProfile(None, frequency=10, size_bytes=1),      # ratio 10
        PatternProfile(None, frequency=50, size_bytes=100),    # ratio 0.5
    ]
    chosen = greedy_knapsack(profs, budget_bytes=101)
    assert chosen == [0, 1]


def test_dynamic_placement_evicts_cold():
    q_hot = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    q_cold = QueryGraph([TriplePattern("?x", 1, "?y")], [])
    hot, cold = pattern_of(q_hot), pattern_of(q_cold)
    dp = DynamicPlacement(budget_bytes=100)
    dp.set_size(hot, 80)
    dp.set_size(cold, 80)
    dp.observe(cold, 5)
    added, evicted = dp.rebalance()
    assert [p.key for p in added] == [cold.key]
    for _ in range(10):
        dp.decay_round()
        dp.observe(hot, 10)
    added, evicted = dp.rebalance()
    assert [p.key for p in added] == [hot.key]
    assert [p.key for p in evicted] == [cold.key]
    assert dp.used_bytes() == 80
