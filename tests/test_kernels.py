"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.join_probe import (probe_sorted, probe_sorted_many,
                                      scan_probe)
from repro.kernels.segment_mp import segment_sum_sorted
from repro.kernels.triple_scan import triple_scan

KEY = jax.random.PRNGKey(0)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# -- flash attention -----------------------------------------------------------

FLASH_CASES = [
    # B, H, Hkv, S, d, window, softcap
    (2, 4, 2, 128, 32, 0, 0.0),
    (1, 4, 4, 256, 64, 0, 50.0),
    (2, 8, 2, 256, 32, 64, 0.0),
    (1, 2, 1, 64, 16, 32, 30.0),
    (1, 8, 8, 512, 64, 128, 0.0),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, H, Hkv, S, d, win, cap = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_attention(q, k, v, window=win, softcap=cap, bq=64, bk=64,
                          interpret=True)
    want = ref.mha_reference(q, k, v, window=win, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_block_shape_sweep():
    B, H, S, d = 1, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.float32)
    want = ref.mha_reference(q, k, v)
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# -- decode attention ----------------------------------------------------------

DECODE_CASES = [
    # B, H, Hkv, S, d, window
    (2, 4, 2, 256, 32, 0),
    (1, 8, 1, 512, 64, 0),
    (3, 4, 4, 128, 32, 48),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(case, dtype):
    B, H, Hkv, S, d, win = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, d), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = decode_attention(q, kc, vc, lengths, window=win, bk=64,
                           interpret=True)
    want = ref.decode_reference(q, kc, vc, lengths, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# -- GNN segment message passing --------------------------------------------------

@pytest.mark.parametrize("E,N,D", [(100, 40, 16), (1000, 64, 32),
                                   (257, 130, 8), (64, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_mp_vs_ref(E, N, D, dtype):
    ks = jax.random.split(KEY, 2)
    msg = jax.random.normal(ks[0], (E, D), dtype)
    dst = jnp.sort(jax.random.randint(ks[1], (E,), 0, N))
    out = segment_sum_sorted(msg, dst, N, bn=32, bc=64, interpret=True)
    # oracle in fp32: the kernel accumulates in fp32 scratch regardless of
    # input dtype (more accurate than a bf16 pairwise segment_sum)
    want = ref.segment_sum_sorted_reference(msg.astype(jnp.float32), dst, N)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **tol(dtype))


def test_segment_mp_empty_and_hot_nodes():
    # one node receives everything; most receive nothing
    E, N, D = 512, 64, 16
    msg = jnp.ones((E, D), jnp.float32)
    dst = jnp.zeros((E,), jnp.int32).at[256:].set(63)
    dst = jnp.sort(dst)
    out = segment_sum_sorted(msg, dst, N, bn=16, bc=128, interpret=True)
    assert float(out[0, 0]) == 256.0
    assert float(out[63, 0]) == 256.0
    assert float(jnp.abs(out[1:63]).max()) == 0.0


# -- embedding bag ------------------------------------------------------------------

@pytest.mark.parametrize("B,F,NNZ,V,D", [(4, 3, 4, 100, 16),
                                         (2, 8, 2, 1000, 32),
                                         (8, 1, 6, 50, 64)])
@pytest.mark.parametrize("combiner", ["mean", "sum"])
def test_embedding_bag_vs_ref(B, F, NNZ, V, D, combiner):
    ks = jax.random.split(KEY, 3)
    table = jax.random.normal(ks[0], (V, D), jnp.float32)
    ids = jax.random.randint(ks[1], (B, F, NNZ), 0, V)
    mask = (jax.random.uniform(ks[2], (B, F, NNZ)) < 0.7).astype(jnp.float32)
    mask = mask.at[:, :, 0].set(1.0)
    out = embedding_bag_pallas(table, ids, mask, combiner=combiner,
                               interpret=True)
    want = ref.embedding_bag_reference(table, ids, mask, combiner=combiner)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- triple scan -------------------------------------------------------------------

@pytest.mark.parametrize("T", [100, 2048, 5000])
def test_triple_scan_vs_ref(T):
    rng = np.random.default_rng(0)
    triples = jnp.asarray(rng.integers(0, 50, (T, 3)), jnp.int32)
    for (s, p, o) in [(-1, 3, -1), (7, -1, -1), (-1, -1, -1), (1, 2, 3),
                      (-1, 4, 9)]:
        out = triple_scan(triples, jnp.asarray([s, p, o]), bt=512,
                          interpret=True)
        want = ref.triple_scan_reference(triples, s, p, o)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_triple_scan_agrees_with_matcher_candidates():
    """The kernel implements the matcher's candidate scan semantics."""
    from repro.rdf.generator import generate_watdiv_like
    g = generate_watdiv_like(scale=0.3, seed=5)
    triples = jnp.asarray(g.store.triples(), jnp.int32)
    pid = 3
    mask = triple_scan(triples, jnp.asarray([-1, pid, -1]), interpret=True)
    got = np.flatnonzero(np.asarray(mask))
    want = np.sort(g.store.pred_tids(pid))
    np.testing.assert_array_equal(got, want)


# -- sorted-probe join ---------------------------------------------------------

# (K, P): empty keys, single element, chunk boundaries around bk/bp
# multiples, sizes forcing multi-block accumulation
PROBE_CASES = [(0, 7), (1, 1), (100, 33), (512, 512), (513, 511),
               (2048, 129), (5000, 1000)]


@pytest.mark.parametrize("K,P", PROBE_CASES)
def test_probe_sorted_vs_searchsorted(K, P):
    """Bit-identical to the matcher's np.searchsorted join probe — with
    duplicate keys and probe values outside the key range on both sides."""
    rng = np.random.default_rng(K * 1009 + P)
    keys = np.sort(rng.integers(0, 60, K)).astype(np.int32)
    probes = rng.integers(-10, 90, P).astype(np.int32)
    lo, hi = probe_sorted(jnp.asarray(keys), jnp.asarray(probes),
                          bk=512, bp=128, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(lo), np.searchsorted(keys, probes, side="left"))
    np.testing.assert_array_equal(
        np.asarray(hi), np.searchsorted(keys, probes, side="right"))
    # jnp oracle agrees with the numpy ground truth above
    rlo, rhi = ref.probe_sorted_reference(jnp.asarray(keys),
                                          jnp.asarray(probes))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))


def test_probe_sorted_many_vs_searchsorted():
    rng = np.random.default_rng(7)
    keys = np.sort(rng.integers(0, 500, 777)).astype(np.int32)
    probes = rng.integers(-5, 600, (5, 300)).astype(np.int32)
    lo, hi = probe_sorted_many(jnp.asarray(keys), jnp.asarray(probes),
                               bk=256, bp=128, interpret=True)
    for q in range(probes.shape[0]):
        np.testing.assert_array_equal(
            np.asarray(lo[q]), np.searchsorted(keys, probes[q], side="left"))
        np.testing.assert_array_equal(
            np.asarray(hi[q]), np.searchsorted(keys, probes[q], side="right"))


@pytest.mark.parametrize("T,K,bt", [(100, 50, 512), (2500, 0, 512),
                                    (2048, 2048, 1024), (33, 5, 2048)])
def test_scan_probe_fused_vs_ref(T, K, bt):
    """Fused scan+first-join kernel vs the unfused oracle: empty key
    columns, chunk-boundary block sizes, all-wildcard patterns, both
    probe columns."""
    rng = np.random.default_rng(T + K)
    triples = jnp.asarray(rng.integers(0, 60, (T, 3)), jnp.int32)
    keys = jnp.asarray(np.sort(rng.integers(0, 60, K)), jnp.int32)
    for pat in [(-1, 3, -1), (-1, -1, -1), (7, 2, -1), (1, 2, 3)]:
        for col in (0, 2):
            m, lo, hi = scan_probe(triples, jnp.asarray(pat, jnp.int32),
                                   keys, col, bt=bt, bk=bt, interpret=True)
            wm, wlo, whi = ref.scan_probe_reference(triples, *pat, keys, col)
            np.testing.assert_array_equal(np.asarray(m), np.asarray(wm))
            np.testing.assert_array_equal(np.asarray(lo), np.asarray(wlo))
            np.testing.assert_array_equal(np.asarray(hi), np.asarray(whi))


def test_scan_probe_rejects_predicate_column():
    with pytest.raises(ValueError):
        scan_probe(jnp.zeros((8, 3), jnp.int32),
                   jnp.asarray([-1, -1, -1], jnp.int32),
                   jnp.zeros(4, jnp.int32), col=1, interpret=True)


@pytest.mark.requires_accelerator
def test_probe_sorted_compiled_matches_interpret():
    """Compiled (Mosaic) and interpret mode agree — runs on real hardware
    only; the CPU CI lane auto-skips via the marker."""
    rng = np.random.default_rng(0)
    keys = jnp.asarray(np.sort(rng.integers(0, 500, 4096)), jnp.int32)
    probes = jnp.asarray(rng.integers(-5, 600, 1024), jnp.int32)
    ci = probe_sorted(keys, probes, interpret=True)
    cc = probe_sorted(keys, probes, interpret=False)
    for a, b in zip(ci, cc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.requires_accelerator
def test_triple_scan_compiled_matches_interpret():
    rng = np.random.default_rng(1)
    triples = jnp.asarray(rng.integers(0, 50, (4096, 3)), jnp.int32)
    pat = jnp.asarray([-1, 3, -1])
    np.testing.assert_array_equal(
        np.asarray(triple_scan(triples, pat, interpret=True)),
        np.asarray(triple_scan(triples, pat, interpret=False)))
