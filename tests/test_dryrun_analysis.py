"""Dry-run analysis machinery: collective parser + roofline arithmetic."""

import numpy as np

from repro.launch.dryrun import COLLECTIVE_RE, collective_bytes


HLO_SNIPPET = """
  %all-reduce.1 = f32[64,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[8,256]{1,0} all-gather(%y), dimensions={0}
  %rs.2 = reduce-scatter(%z)
  %all-to-all.5 = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot.3 = f32[64,64]{1,0} dot(%p, %q)
"""


def test_collective_parser_counts_and_bytes():
    # post-SPMD HLO form: "<name> = <shape> <op>(...)", incl. custom names
    txt = """
  %all-reduce.1 = f32[64,1024]{1,0} all-reduce(%x), replica_groups={}
  %myname = bf16[8,256]{1,0} all-gather(%y), dimensions={0}
  %atoa = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%a, %b)
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot.3 = f32[64,64]{1,0} dot(%p, %q)
"""
    out = collective_bytes(txt)
    by = out["bytes_by_kind"]
    assert by["all-reduce"] == 64 * 1024 * 4
    assert by["all-gather"] == 8 * 256 * 2
    assert by["all-to-all"] == 2 * 16 * 16 * 4
    assert by["collective-permute"] == 128
    assert "dot" not in by
    assert out["total_bytes"] == sum(by.values())
    assert out["ops_by_kind"]["all-reduce"] == 1


def test_roofline_correction_math():
    from benchmarks.roofline import corrected
    rec = {
        "flops": 100.0, "bytes_accessed": 10.0,
        "collectives": {"total_bytes": 4.0},
        "probe": {"flops": 7.0, "bytes_accessed": 1.0,
                  "collectives": {"total_bytes": 0.5}},
        "probe_repeat": 3,
    }
    tot = corrected(rec)
    assert tot["flops"] == 100 + 3 * 7
    assert tot["bytes"] == 10 + 3 * 1
    assert tot["coll_bytes"] == 4 + 3 * 0.5
    rec2 = {k: v for k, v in rec.items() if not k.startswith("probe")}
    tot2 = corrected(rec2)
    assert tot2["flops"] == 100.0


def test_lm_model_flops_sane():
    from benchmarks.roofline import model_flops
    # qwen3-0.6b train: 6 * N_active * tokens / chips, N ~ 0.75e9 total
    f = model_flops("qwen3-0.6b", "train_4k", 256)
    assert 1e12 < f < 1e14
    # decode is tiny per step
    fd = model_flops("qwen3-0.6b", "decode_32k", 256)
    assert fd < f / 1000
    # MoE uses ACTIVE params: phi active ~6.6B of 42B
    from repro.configs.registry import get_spec
    cfg = get_spec("phi3.5-moe-42b-a6.6b").config
    assert 35e9 < cfg.param_count() < 50e9
    assert 5e9 < cfg.active_param_count() < 9e9
