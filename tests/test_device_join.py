"""Device-resident join pipeline (PR 7): eligibility, device-vs-host
result parity, the ONE-bulk-transfer-per-batch contract, transparent
fallback, staged-view invalidation after deltas, and capacity parity."""

import numpy as np
import pytest

from repro.rdf.deltas import TripleDelta
from repro.rdf.generator import generate_watdiv_like
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.device_join import device_eligible
from repro.sparql.engine import JaxBackend, QueryEngine
from repro.sparql.matcher import MatchCapacityError, match_bgp, plan_bgp
from repro.sparql.query import QueryGraph, TriplePattern

from test_engine import sol_rows

# bound-predicate star / path / single-pattern shapes — the device class
DEVICE_SHAPES = [
    [TriplePattern("?x", 0, "?y")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?y", 1, "?z")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?x", 1, "?z")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?y", 1, "?z"),
     TriplePattern("?z", 2, "?w")],
    [TriplePattern(3, 0, "?y"), TriplePattern("?y", 1, "?z")],
]

# shapes the device path must decline: variable predicates (wildcard seed
# fans out over shards; var-pred join steps), repeated variables, closing
# joins with both endpoints bound (equality-masked)
HOST_SHAPES = [
    [TriplePattern("?x", "?p", "?y")],
    [TriplePattern("?x", 0, "?x")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?y", "?p", "?z")],
    [TriplePattern("?x", 0, "?y"), TriplePattern("?y", 1, "?z"),
     TriplePattern("?z", 2, "?x")],                      # triangle closes
]


def _stores(scale=0.5, seed=11, shards=4):
    g = generate_watdiv_like(scale=scale, seed=seed)
    return g.store, ShardedTripleStore.from_store(g.store, shards)


def _qs(shapes):
    return [QueryGraph(pats, []) for pats in shapes]


def test_device_eligibility_matrix():
    mono, sh = _stores()
    for pats in DEVICE_SHAPES:
        q = QueryGraph(pats, [])
        assert device_eligible(sh, q, plan_bgp(sh, q)), pats
    for pats in HOST_SHAPES:
        q = QueryGraph(pats, [])
        assert not device_eligible(sh, q, plan_bgp(sh, q)), pats
    # a monolithic store takes wildcard seeds (single flat part) ...
    q = QueryGraph([TriplePattern("?s", "?p", "?o")], [])
    assert device_eligible(mono, q, plan_bgp(mono, q))
    # ... and empty stores decline everything
    empty = TripleStore(np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.int64), 4, 2)
    q = QueryGraph(DEVICE_SHAPES[0], [])
    assert not device_eligible(empty, q, plan_bgp(empty, q))


def _full_rows(res):
    """Multiset of (sorted-var bindings + pattern-order edge ids) rows —
    row order and variable column order are backend implementation
    details, the row CONTENTS are not."""
    idx = [res.var_names.index(v) for v in sorted(res.var_names)]
    rows = np.concatenate([res.bindings[:, idx], res.edge_ids], axis=1)
    return sorted(map(tuple, rows.tolist()))


@pytest.mark.parametrize("sharded", [False, True])
def test_device_results_equal_host(sharded):
    """Bindings AND edge ids through the device pipeline match the numpy
    backend bit-for-bit, for eligible and fallback shapes alike."""
    mono, sh = _stores()
    store = sh if sharded else mono
    qs = _qs(DEVICE_SHAPES + HOST_SHAPES)
    eng_dev = QueryEngine(backend=JaxBackend(bt=512))
    eng_ref = QueryEngine(backend="numpy")
    for q, res, ref in zip(qs, eng_dev.execute_batch(store, qs),
                           eng_ref.execute_batch(store, qs)):
        assert _full_rows(res) == _full_rows(ref), q.patterns
    n_dev = sum(device_eligible(store, q, plan_bgp(store, q)) for q in qs)
    assert eng_dev.stats.device_queries == n_dev >= len(DEVICE_SHAPES)
    assert eng_dev.stats.device_fallbacks == len(qs) - n_dev > 0
    assert eng_dev.stats.join.joins_device > 0
    assert eng_ref.stats.join.joins_device == 0


def test_single_bulk_transfer_per_batch():
    """THE acceptance criterion: a batch whose every cache-missed query is
    device-eligible costs exactly ONE device->host transfer."""
    _, sh = _stores()
    qs = _qs(DEVICE_SHAPES)
    bk = JaxBackend(bt=512)
    eng = QueryEngine(backend=bk)
    before = bk.host_transfers
    eng.execute_batch(sh, qs)
    assert bk.host_transfers - before == 1
    # EngineStats mirrors the backend's cumulative totals
    assert eng.stats.host_transfers == bk.host_transfers
    assert eng.stats.host_transfer_bytes == bk.host_transfer_bytes > 0
    assert eng.stats.scalar_syncs == bk.scalar_syncs > 0
    assert eng.stats.device_queries == len(qs)
    assert eng.stats.device_fallbacks == 0

    # a mixed batch adds exactly one more (the host prescan's bulk fetch)
    before = bk.host_transfers
    eng.clear_cache()
    eng.execute_batch(sh, _qs(DEVICE_SHAPES + HOST_SHAPES))
    assert bk.host_transfers - before == 2

    # a warm batch is served from the result cache: zero transfers
    before, hits = bk.host_transfers, eng.stats.cache_hits
    eng.execute_batch(sh, _qs(DEVICE_SHAPES))
    assert bk.host_transfers - before == 0
    assert eng.stats.cache_hits - hits == len(DEVICE_SHAPES)


def test_device_resident_off_falls_back():
    _, sh = _stores()
    qs = _qs(DEVICE_SHAPES)
    eng = QueryEngine(backend=JaxBackend(bt=512, device_resident=False))
    ref = QueryEngine(backend="numpy")
    for res, want in zip(eng.execute_batch(sh, qs),
                         ref.execute_batch(sh, qs)):
        assert sol_rows(res) == sol_rows(want)
    assert eng.stats.device_queries == 0
    assert eng.stats.join.joins_device == 0


def test_backend_mode_reported():
    assert QueryEngine(backend="numpy").stats.backend_mode == "numpy"
    mode = QueryEngine(backend="jax").stats.backend_mode
    assert mode in ("jax-interpret", "jax-compiled")
    assert QueryEngine(
        backend=JaxBackend(interpret=True)).stats.backend_mode \
        == "jax-interpret"


def test_capacity_error_parity():
    """The device join raises MatchCapacityError at the same max_rows
    threshold as the host (no equality masks -> raw fan-out IS the
    surviving row count)."""
    n = 200
    s = np.concatenate([np.arange(n), np.zeros(n, np.int64)])
    p = np.concatenate([np.zeros(n, np.int64), np.ones(n, np.int64)])
    o = np.concatenate([np.zeros(n, np.int64), np.arange(n)])
    store = ShardedTripleStore(s, p, o, n + 1, 2, num_shards=2)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?y", 1, "?z")], [])
    want = match_bgp(store, q).num_matches
    assert want == n * n
    ok = QueryEngine(backend=JaxBackend(bt=512), max_rows=want)
    assert ok.execute(store, q).num_matches == want
    assert ok.stats.device_queries == 1
    tight = QueryEngine(backend=JaxBackend(bt=512), max_rows=want - 1)
    with pytest.raises(MatchCapacityError):
        tight.execute(store, q)


def test_delta_invalidates_staged_views():
    """Staged device pred_index views are keyed by shard version: after an
    in-place delta the next batch re-stages and stays correct."""
    rng = np.random.default_rng(31)
    s, p, o = (rng.integers(0, 20, 80), rng.integers(0, 4, 80),
               rng.integers(0, 20, 80))
    sh = ShardedTripleStore(s, p, o, 20, 4, num_shards=2)
    mono = TripleStore(s, p, o, 20, 4)
    q = QueryGraph([TriplePattern("?x", 0, "?y"),
                    TriplePattern("?y", 1, "?z")], [])
    bk = JaxBackend(bt=512)
    eng = QueryEngine(backend=bk)
    assert sol_rows(eng.execute(sh, q)) == sol_rows(match_bgp(mono, q))
    staged_before = len(bk._staged_views)
    assert staged_before > 0
    # rewrite part of pred 1 in place (new shard version)
    new_rows = np.stack([np.arange(5), np.ones(5, np.int64),
                         np.arange(5) + 5], axis=1)
    sh.apply_delta(TripleDelta(base_version=sh.version, add=new_rows))
    mono2 = TripleStore(*sh.triples().T, 20, 4)
    assert sol_rows(eng.execute(sh, q)) == sol_rows(match_bgp(mono2, q))
    assert eng.stats.device_queries == 2      # device path both times


def test_staged_view_lru_bounded():
    _, sh = _stores(scale=0.3, seed=7, shards=2)
    bk = JaxBackend(bt=512)
    bk.max_staged_views = 2
    eng = QueryEngine(backend=bk)
    qs = [QueryGraph([TriplePattern("?x", pid, "?y"),
                      TriplePattern("?y", (pid + 1) % 4, "?z")], [])
          for pid in range(4)]
    ref = QueryEngine(backend="numpy")
    for res, want in zip(eng.execute_batch(sh, qs),
                         ref.execute_batch(sh, qs)):
        assert sol_rows(res) == sol_rows(want)
    assert len(bk._staged_views) <= 2
