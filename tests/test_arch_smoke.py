"""Per-arch smoke tests: reduced config, one forward/train step, finite.

The FULL configs are exercised compile-only by the dry-run; these tests run
real numerics on CPU with the same model code and a shrunken topology.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow   # full-arch numerics: minutes on CPU

from repro.configs.registry import ARCH_IDS, all_cells, get_spec
from repro.launch.train import make_batch_iter, reduce_config
from repro.models.common import AxisRules
from repro.models.gnn import gnn_init, gnn_loss
from repro.models.recsys import (init_recsys_params, recsys_loss,
                                 recsys_score, retrieval_topk)
from repro.models.transformer import (init_kv_cache, init_lm_params,
                                      lm_decode_step, lm_forward, lm_loss)
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import make_train_step

RULES = AxisRules(batch=(), fsdp=None, tp=None)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    spec = get_spec(arch_id)
    cfg = reduce_config(spec)
    key = jax.random.PRNGKey(0)
    if spec.family == "lm":
        params = init_lm_params(cfg, key)
        loss_fn = lambda p, b: lm_loss(cfg, p, b, RULES)      # noqa: E731
    elif spec.family == "gnn":
        params = gnn_init(cfg, key)
        loss_fn = lambda p, b: gnn_loss(cfg, p, b, RULES)     # noqa: E731
    else:
        params = init_recsys_params(cfg, key)
        loss_fn = lambda p, b: recsys_loss(cfg, p, b, RULES)  # noqa: E731

    from repro.optim.adamw import adamw_init
    batch = next(make_batch_iter(spec, cfg, batch_size=4, seed=1))
    step = jax.jit(make_train_step(loss_fn, AdamWConfig(peak_lr=1e-3)))
    opt = adamw_init(params)
    p2, opt2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    assert int(opt2["step"]) == 1
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p2)
    assert max(jax.tree.leaves(delta)) > 0
    # output shapes per family
    if spec.family == "lm":
        logits, aux = jax.jit(
            lambda p, t: lm_forward(cfg, p, t, RULES))(params, batch)
        assert logits.shape == (*batch.shape, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits.astype(jnp.float32))).all()


@pytest.mark.parametrize("arch_id",
                         [a for a in ARCH_IDS
                          if get_spec(a).family == "lm"])
def test_smoke_lm_decode(arch_id):
    spec = get_spec(arch_id)
    cfg = reduce_config(spec)
    params = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    cache = init_kv_cache(cfg, 2, 16)
    logits_all, _ = jax.jit(
        lambda p, t: lm_forward(cfg, p, t, RULES))(params, toks)
    dec = jax.jit(lambda p, c, t, i: lm_decode_step(cfg, p, c, t, i, RULES))
    lg = None
    for i in range(5):
        lg, cache = dec(params, cache, toks[:, i:i + 1], jnp.int32(i))
    a = np.asarray(lg[:, 0].astype(jnp.float32))
    b = np.asarray(logits_all[:, 4].astype(jnp.float32))
    scale = max(1.0, float(np.abs(b).max()))
    assert np.abs(a - b).max() < 0.06 * scale, arch_id


def test_smoke_recsys_serving_paths():
    spec = get_spec("wide-deep")
    cfg = reduce_config(spec)
    params = init_recsys_params(cfg, jax.random.PRNGKey(0))
    batch = next(make_batch_iter(spec, cfg, batch_size=8, seed=2))
    s = jax.jit(lambda p, b: recsys_score(cfg, p, b, RULES))(params, batch)
    assert s.shape == (8,) and bool(((s >= 0) & (s <= 1)).all())
    one = {k: v[:1] for k, v in batch.items()}
    vals, idx = jax.jit(
        lambda p, b: retrieval_topk(cfg, p, b, RULES, k=5))(params, one)
    assert vals.shape == (1, 5)
    assert bool((vals[0, :-1] >= vals[0, 1:]).all())


def test_registry_covers_assignment():
    """40 declared cells; skips only where the brief allows them."""
    cells = all_cells()
    skips = {(a, s) for a in ARCH_IDS
             for s in get_spec(a).skip_shapes}
    assert len(cells) + len(skips) == 40
    # only long_500k may be skipped, and only for pure full-attention LMs
    for (a, s) in skips:
        assert s == "long_500k"
        assert get_spec(a).family == "lm"
    assert ("gemma2-2b", "long_500k") in cells  # hybrid arch runs it
