"""RDF substrate + SPARQL matcher: unit + property tests vs oracle."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared test dep; deterministic fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.rdf.dictionary import Dictionary
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.sparql.matcher import match_bgp, match_oracle
from repro.sparql.query import (ParseError, QueryGraph, TriplePattern,
                                parse_sparql)


def small_store():
    d = Dictionary()
    ents = {n: d.add_entity(n) for n in
            ["a", "b", "c", "d", "e"]}
    preds = {n: d.add_predicate(n) for n in ["knows", "likes"]}
    tr = [
        ("a", "knows", "b"), ("b", "knows", "c"), ("a", "knows", "c"),
        ("c", "likes", "d"), ("b", "likes", "d"), ("d", "knows", "a"),
        ("e", "likes", "e"),
    ]
    s = np.array([ents[x[0]] for x in tr])
    p = np.array([preds[x[1]] for x in tr])
    o = np.array([ents[x[2]] for x in tr])
    return TripleStore(s, p, o, d.num_entities, d.num_predicates), d, ents, preds


def test_store_dedup_and_stats():
    st_, d, ents, preds = small_store()
    assert st_.num_triples == 7
    assert st_.pred_count[preds["knows"]] == 4
    assert st_.pred_count[preds["likes"]] == 3
    assert st_.pred_distinct_s[preds["likes"]] == 3
    assert st_.pred_distinct_o[preds["likes"]] == 2


def test_subgraph_preserves_ids():
    st_, d, ents, preds = small_store()
    sub = st_.subgraph(np.array([0, 1]))
    assert sub.num_triples == 2
    assert sub.num_entities == st_.num_entities
    # entity ids are global — decoding still works
    for sid in sub.s:
        d.entity(int(sid))


def test_parse_and_match_chain():
    st_, d, ents, preds = small_store()
    q = parse_sparql(
        "SELECT ?x ?y WHERE { ?x <knows> ?y . ?y <likes> ?z }", d)
    res = match_bgp(st_, q)
    sols, vs = match_oracle(st_, q)
    got = {tuple(row[[res.var_names.index(v) for v in vs]])
           for row in res.bindings}
    assert got == sols
    assert res.num_matches == len(sols) > 0


def test_match_constant_anchor():
    st_, d, ents, preds = small_store()
    q = parse_sparql("SELECT ?y WHERE { <a> <knows> ?y }", d)
    res = match_bgp(st_, q)
    assert sorted(res.column("?y").tolist()) == sorted(
        [ents["b"], ents["c"]])


def test_match_var_predicate():
    st_, d, ents, preds = small_store()
    q = QueryGraph([TriplePattern(ents["a"], "?p", "?y")], ["?p", "?y"])
    res = match_bgp(st_, q)
    sols, vs = match_oracle(st_, q)
    got = {tuple(row[[res.var_names.index(v) for v in vs]])
           for row in res.bindings}
    assert got == sols


def test_match_self_loop_var():
    st_, d, ents, preds = small_store()
    q = QueryGraph([TriplePattern("?x", preds["likes"], "?x")], ["?x"])
    res = match_bgp(st_, q)
    assert res.column("?x").tolist() == [ents["e"]]


def test_match_cycle():
    st_, d, ents, preds = small_store()
    # triangle a->b->c with a->c
    q = QueryGraph([
        TriplePattern("?x", preds["knows"], "?y"),
        TriplePattern("?y", preds["knows"], "?z"),
        TriplePattern("?x", preds["knows"], "?z"),
    ], ["?x", "?y", "?z"])
    res = match_bgp(st_, q)
    sols, vs = match_oracle(st_, q)
    got = {tuple(row[[res.var_names.index(v) for v in vs]])
           for row in res.bindings}
    assert got == sols
    assert (ents["a"], ents["b"], ents["c"]) in got


def test_edge_ids_are_matches():
    st_, d, ents, preds = small_store()
    q = parse_sparql("SELECT ?x ?y ?z WHERE { ?x <knows> ?y . ?y <likes> ?z }", d)
    res = match_bgp(st_, q)
    # each row's edge ids must reproduce the bindings
    for r in range(res.num_matches):
        e0, e1 = res.edge_ids[r]
        assert st_.s[e0] == res.column("?x")[r]
        assert st_.o[e0] == res.column("?y")[r]
        assert st_.s[e1] == res.column("?y")[r]
        assert st_.o[e1] == res.column("?z")[r]


def test_parse_errors():
    st_, d, ents, preds = small_store()
    with pytest.raises(ParseError):
        parse_sparql("SELECT ?x WHERE { ?x <nosuchpred> ?y }", d)
    with pytest.raises(ParseError):
        parse_sparql("ASK { ?x <knows> ?y }", d)


def test_generator_deterministic_and_nonempty():
    g1 = generate_watdiv_like(scale=1.0, seed=7)
    g2 = generate_watdiv_like(scale=1.0, seed=7)
    assert g1.store.num_triples == g2.store.num_triples > 1000
    assert np.array_equal(g1.store.triples(), g2.store.triples())


def test_workload_parses_and_matches():
    g = generate_watdiv_like(scale=0.5, seed=3)
    queries = workload_sparql(g, 10, seed=1)
    assert len(queries) == 10
    nonempty = 0
    for qs in queries:
        q = parse_sparql(qs, g.dictionary)
        assert q.is_weakly_connected()
        res = match_bgp(g.store, q)
        nonempty += res.num_matches > 0
    assert nonempty >= 5  # most template instantiations hit data


# ---------------------------------------------------------------------------
# property tests: random graphs + random small queries vs oracle
# ---------------------------------------------------------------------------

@st.composite
def random_case(draw):
    n_ent = draw(st.integers(3, 8))
    n_pred = draw(st.integers(1, 3))
    n_trip = draw(st.integers(1, 15))
    s = draw(st.lists(st.integers(0, n_ent - 1), min_size=n_trip,
                      max_size=n_trip))
    p = draw(st.lists(st.integers(0, n_pred - 1), min_size=n_trip,
                      max_size=n_trip))
    o = draw(st.lists(st.integers(0, n_ent - 1), min_size=n_trip,
                      max_size=n_trip))
    n_pat = draw(st.integers(1, 3))
    pats = []
    var_pool = ["?a", "?b", "?c", "?d"]
    for _ in range(n_pat):
        def term():
            if draw(st.booleans()):
                return draw(st.sampled_from(var_pool))
            return draw(st.integers(0, n_ent - 1))
        pred = (draw(st.sampled_from(var_pool))
                if draw(st.integers(0, 4)) == 0
                else draw(st.integers(0, n_pred - 1)))
        pats.append(TriplePattern(term(), pred, term()))
    return (np.array(s), np.array(p), np.array(o), n_ent, n_pred, pats)


@given(random_case())
@settings(max_examples=60, deadline=None)
def test_matcher_equals_oracle(case):
    s, p, o, n_ent, n_pred, pats = case
    store = TripleStore(s, p, o, n_ent, n_pred)
    q = QueryGraph(pats, [])
    res = match_bgp(store, q)
    sols, vs = match_oracle(store, q)
    if not vs:  # all-constant query: matcher returns unit/empty table
        assert (res.num_matches > 0) == (len(sols) > 0)
        return
    got = {tuple(row[[res.var_names.index(v) for v in vs]])
           for row in res.bindings}
    assert got == sols
