"""Collaborative partial evaluation (PR 8): the three-way scheduler's
partial (edge-set -> cloud assembler) path must be bit-identical to the
cloud-only oracle on both backends x both store kinds — star / path /
flower queries straddling 2-3 edges — including under delta-rebalance
mid-run (stale partial plans must fall back, never assemble), plus the
serving-pool analogue and the endpoint explain surface."""

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.core.pattern import pattern_of
from repro.edge.system import PARTIAL, EdgeCloudSystem
from repro.rdf.generator import generate_watdiv_like
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.algebra import compile_query, evaluate_many
from repro.sparql.partial_eval import execute_partial_batch, plan_partial
from repro.sparql.query import parse_query, parse_sparql

from test_engine import BACKENDS, sol_rows

# per-edge resident leaves: no single edge holds every leaf of any test
# query, so the binary scheduler's only executable option is cloud
LEAVES = {
    0: ["SELECT ?x ?p WHERE { ?x <likes> ?p }"],
    1: ["SELECT ?p ?gn WHERE { ?p <hasGenre> ?gn }",
        "SELECT ?x ?y WHERE { ?x <follows> ?y }"],
    2: ["SELECT ?x ?c WHERE { ?x <country> ?c }"],
}
# nested groups compile to separate BGP leaves; each query straddles the
# residency of 2-3 edges
QUERIES = {
    "path2": "SELECT ?x ?gn WHERE { { ?x <likes> ?p } "
             "{ ?p <hasGenre> ?gn } }",
    "star3": "SELECT ?x ?y ?c WHERE { { ?x <likes> ?p } "
             "{ ?x <follows> ?y } { ?x <country> ?c } }",
    "flower": "SELECT ?x ?gn ?c WHERE { { ?x <likes> ?p } "
              "{ ?p <hasGenre> ?gn } { ?x <country> ?c } }",
}


@pytest.fixture(scope="module")
def graph():
    return generate_watdiv_like(scale=1.0, seed=42)


def partial_params(K=3, N=4):
    """Bandwidth-constrained regime: slow user->cloud uplink, congested
    cloud compute, fast edges and datacenter backhaul — partial wins."""
    return SystemParams(
        F=np.full(K, 1.0e9),
        r_edge=np.full((N, K), 75e6),
        r_cloud=np.full(N, 5e6),
        assoc=np.ones((N, K), dtype=bool),
        r_backhaul=np.full(K, 1e9),
        F_cloud=0.05e9,
    )


def make_system(g, store, backend="numpy", enable_partial=True,
                params=None):
    sys_ = EdgeCloudSystem(store, g.dictionary,
                           params or partial_params(),
                           storage_budgets=10_000_000, backend=backend,
                           enable_partial=enable_partial)
    for k, texts in LEAVES.items():
        sys_.edges[k].deploy(store, [pattern_of(parse_sparql(
            t, g.dictionary)) for t in texts])
    return sys_


def compile_(g, text):
    return compile_query(parse_query(text, g.dictionary), g.dictionary)


def edge_map(sys_):
    return {es.server_id: es for es in sys_.edges}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["mono", "sharded"])
def test_partial_matches_cloud_oracle(graph, backend, kind):
    """Oracle-equivalence matrix: every shape routes through the partial
    path and returns exactly the cloud-only result, with honest
    accounting (shipped bytes, contributing servers, per-server wall)."""
    g = graph
    store = (g.store if kind == "mono"
             else ShardedTripleStore.from_store(g.store, 4))
    sys_ = make_system(g, store, backend=backend)
    for shape, text in QUERIES.items():
        plan = compile_(g, text)
        rep = sys_.run_round_batched([(0, plan)], policy="bnb",
                                     collect_results=True)
        o = rep.outcomes[0]
        assert o.assigned_to == PARTIAL, shape
        assert rep.partial_queries == 1 and rep.partial_fallbacks == 0
        assert rep.partial_bytes_shipped > 0
        assert rep.partial_bytes_shipped == int(o.shipped_bits // 8)
        assert len(o.partial_servers) >= 2, shape   # straddles 2-3 edges
        assert o.modeled_latency > 0 and o.realized_latency > 0
        # contributing edges and the assembler both did accounted work
        for sid in o.partial_servers:
            assert rep.server_wall_seconds.get(sid, 0.0) >= 0.0
        assert -1 in rep.server_wall_seconds
        oracle = evaluate_many([plan], store, sys_.engine)[0]
        assert sol_rows(rep.results[0]) == sol_rows(oracle), shape


def test_partial_disabled_keeps_binary_assignment(graph):
    g = graph
    sys_ = make_system(g, g.store, enable_partial=False)
    plan = compile_(g, QUERIES["path2"])
    rep = sys_.run_round_batched([(0, plan)], policy="bnb",
                                 collect_results=True)
    assert rep.outcomes[0].assigned_to == -1
    assert rep.partial_queries == 0 and rep.partial_bytes_shipped == 0
    oracle = evaluate_many([plan], g.store, sys_.engine)[0]
    assert sol_rows(rep.results[0]) == sol_rows(oracle)


def test_partial_dearer_falls_back_to_cloud(graph):
    """With the paper's legacy free cloud (F_cloud = inf) shipping binding
    tables buys nothing — the scheduler must transparently keep cloud."""
    g = graph
    K, N = 3, 4
    legacy = SystemParams(
        F=np.full(K, 1.0e9),
        r_edge=np.full((N, K), 75e6),
        r_cloud=np.full(N, 5e6),
        assoc=np.ones((N, K), dtype=bool),
    )
    sys_ = make_system(g, g.store, params=legacy)
    plan = compile_(g, QUERIES["flower"])
    rep = sys_.run_round_batched([(0, plan)], policy="bnb",
                                 collect_results=True)
    assert rep.outcomes[0].assigned_to == -1
    assert rep.partial_queries == 0
    assert "dearer" in sys_.explain_assignment(plan, user=0)
    oracle = evaluate_many([plan], g.store, sys_.engine)[0]
    assert sol_rows(rep.results[0]) == sol_rows(oracle)


def test_explain_surfaces_assignment(graph):
    from repro.sparql.endpoint import SparqlEndpoint
    g = graph
    sys_ = make_system(g, g.store)
    ep = SparqlEndpoint.from_system(sys_)
    out = ep.explain(QUERIES["path2"])
    assert "assignment: partial" in out
    assert "cloud assembler" in out
    # the per-server leaf split is rendered below the assignment line
    assert "ES0" in out or "edge 0" in out or "[0, 1]" in out


def test_direct_plan_and_fresh_execute(graph):
    g = graph
    sys_ = make_system(g, g.store)
    plan = compile_(g, QUERIES["path2"])
    pp = plan_partial(plan, sys_.edges)
    assert pp is not None and len(pp.edge_set) == 2
    pex = execute_partial_batch([pp], g.store, sys_.engine,
                                edge_map(sys_))[0]
    assert not pex.fallback
    oracle = evaluate_many([plan], g.store, sys_.engine)[0]
    assert sol_rows(pex.result) == sol_rows(oracle)
    assert sum(pex.per_server_bits.values()) > 0


def test_stale_plan_falls_back_never_assembles(graph):
    """A partial plan whose edge-store versions moved between planning and
    execution must fall back to one whole-query cloud evaluation."""
    g = graph
    sys_ = make_system(g, g.store)
    plan = compile_(g, QUERIES["path2"])
    pp = plan_partial(plan, sys_.edges)
    # version bump on a contributing edge: re-deploy its leaf
    sys_.edges[0].deploy(g.store, [pattern_of(parse_sparql(
        LEAVES[0][0], g.dictionary))])
    pex = execute_partial_batch([pp], g.store, sys_.engine,
                                edge_map(sys_))[0]
    assert pex.fallback
    oracle = evaluate_many([plan], g.store, sys_.engine)[0]
    assert sol_rows(pex.result) == sol_rows(oracle)


def test_delta_rebalance_hammer(graph):
    """Delta-rebalance mid-run: plans captured before a rebalance must
    fall back exactly when a contributing edge's store version moved;
    results match the oracle in every round, before and after."""
    g = graph
    sys_ = make_system(g, g.store)
    plan = compile_(g, QUERIES["flower"])
    oracle_rows = sol_rows(evaluate_many([plan], g.store,
                                         sys_.engine)[0])
    saw_fallback = saw_fresh = False
    for _ in range(4):
        rep = sys_.run_round_batched([(0, plan)], policy="bnb",
                                     collect_results=True)
        assert sol_rows(rep.results[0]) == oracle_rows
        # capture a partial plan, then rebalance under it
        pp = plan_partial(plan, sys_.edges)
        sys_.rebalance_all(use_deltas=True)
        if pp is None:
            continue   # rebalance gave some edge full residency earlier
        moved = any(
            sys_.edges[sid].store is None
            or sys_.edges[sid].store.version != v
            for sid, v in pp.store_versions.items())
        pex = execute_partial_batch([pp], g.store, sys_.engine,
                                    edge_map(sys_))[0]
        assert pex.fallback == moved
        assert sol_rows(pex.result) == oracle_rows
        saw_fallback |= pex.fallback
        saw_fresh |= not pex.fallback
    # the hammer must exercise the guard at least once (the first
    # rebalance re-places the observed leaves and bumps versions)
    assert saw_fallback
    # post-hammer round still answers correctly whatever the assignment
    rep = sys_.run_round_batched([(0, plan)], policy="bnb",
                                 collect_results=True)
    assert sol_rows(rep.results[0]) == oracle_rows


def test_round_fallback_counted_in_report(graph):
    """A round whose partial plan goes stale mid-flight reassigns to
    cloud, counts the fallback, and ships nothing for that query."""
    g = graph
    sys_ = make_system(g, g.store)
    plan = compile_(g, QUERIES["path2"])
    # sabotage: make planning see current versions, then bump one edge
    # between scheduling and execution by hooking the engine's first use
    tasks = sys_.build_tasks([(0, plan)], include_partial=True)
    opt = tasks.partial_option(0)
    assert opt is not None
    sys_.edges[0].deploy(g.store, [pattern_of(parse_sparql(
        LEAVES[0][0], g.dictionary))])
    pex = execute_partial_batch([opt.plan], g.store, sys_.engine,
                                edge_map(sys_))[0]
    assert pex.fallback
    oracle = evaluate_many([plan], g.store, sys_.engine)[0]
    assert sol_rows(pex.result) == sol_rows(oracle)


def test_serving_pool_partial_option():
    """The serving analogue: a request no replica fully serves may carry a
    partial spec; chosen rows run sub-payloads at the contributing
    replicas and assemble, runnerless contributors fall back whole."""
    from repro.runtime.serving import (PARTIAL as POOL_PARTIAL,
                                       OffloadServingPool, Replica)

    def mk(name):
        return lambda payloads: [f"{name}:{p}" for p in payloads]

    spec = {"replicas": [0, 1], "cycles": [5e5, 2e5],
            "ship_bits": [2e5, 1e5], "assemble_cycles": 5e5,
            "payloads": {0: "subA", 1: "subB"},
            "assemble": lambda subs: "+".join(subs)}
    reqs = [
        {"class_id": 0, "cycles": 7e5, "result_bits": 3e5, "payload": "q0"},
        {"class_id": 9, "cycles": 2e6, "result_bits": 3e5, "payload": "q1",
         "partial": dict(spec)},
        {"class_id": 9, "cycles": 1e5, "result_bits": 3e5, "payload": "q2"},
    ]
    reps = [Replica(0, {0}, 1e9, 75e6, runner=mk("r0")),
            Replica(1, {1}, 1e9, 75e6, runner=mk("r1"))]
    pool = OffloadServingPool(reps, mk("cloud"), cloud_link_bps=5e6,
                              cloud_cycles_per_s=5e7, backhaul_bps=1e9)
    sb = pool.admit(reqs, policy="bnb")
    assert sb.assignments[1] == POOL_PARTIAL
    assert sb.responses[1] == "r0:subA+r1:subB"
    assert sb.responses[0].startswith("r0:")
    assert sb.responses[2].startswith("cloud:")
    assert sb.partial_queries == 1
    assert sb.partial_bytes_shipped == int(3e5 // 8)

    # runnerless contributing replica: the whole request falls back
    reps2 = [Replica(0, {0}, 1e9, 75e6, runner=mk("r0")),
             Replica(1, {1}, 1e9, 75e6, runner=None)]
    pool2 = OffloadServingPool(reps2, mk("cloud"), cloud_link_bps=5e6,
                               cloud_cycles_per_s=5e7, backhaul_bps=1e9)
    sb2 = pool2.admit([reqs[1]], policy="bnb")
    assert sb2.assignments[0] == -1
    assert sb2.responses[0] == "cloud:q1"
    assert sb2.partial_queries == 0
