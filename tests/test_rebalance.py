"""Incremental placement & asynchronous delta-rebalance subsystem.

Covers the delta protocol (round-trip, version guards, per-shard
granularity), the engine's version-granular scan-cache invalidation, the
induced-edge-id memo (zero matcher calls on a no-op rebalance), per-shard
placement budgets + hysteresis, delta-vs-full equivalence and bytes, and
the epoch/barrier handshake (concurrent rebalance parity + feasibility).
"""

import threading

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.core.pattern import pattern_of
from repro.core.placement import (DynamicPlacement, PatternProfile,
                                  greedy_knapsack)
from repro.edge.system import EdgeCloudSystem
from repro.rdf.deltas import DeltaVersionError, TripleDelta, delta_between
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.engine import QueryEngine
from repro.sparql.matcher import match_bgp
from repro.sparql.query import QueryGraph, TriplePattern, parse_sparql


def rows_set(store):
    return np.unique(store.triples(), axis=0)


def sol_rows(res):
    order = sorted(res.var_names)
    idx = [res.var_names.index(v) for v in order]
    return {tuple(r[idx]) for r in res.bindings}


def make_store(kind, s, p, o, ne, npred):
    if kind == "sharded":
        return ShardedTripleStore(s, p, o, ne, npred, num_shards=3)
    return TripleStore(s, p, o, ne, npred)


@pytest.fixture(scope="module")
def small_graph():
    return generate_watdiv_like(scale=0.5, seed=37)


# ---------------------------------------------------------------------------
# delta protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["mono", "sharded"])
def test_delta_round_trip_restores_bytes_and_version(kind):
    rng = np.random.default_rng(1)
    s, p, o = (rng.integers(0, 40, 300), rng.integers(0, 12, 300),
               rng.integers(0, 40, 300))
    st = make_store(kind, s, p, o, 40, 12)
    v0, before = st.version, rows_set(st)
    target = np.unique(np.concatenate(
        [st.triples()[25:], np.array([[0, 5, 1], [2, 7, 3]])]), axis=0)
    d = delta_between(st, target)
    assert not d.is_noop and d.n_evict > 0 and d.n_add > 0
    v1 = st.apply_delta(d)
    assert v1 != v0
    assert np.array_equal(rows_set(st), target)
    v2 = st.apply_delta(d.inverse(v1))
    # content restored exactly; versions are fresh on every apply (a version
    # token identifies contents AND history position — never reused)
    assert np.array_equal(rows_set(st), before)
    assert v2 not in (v0, v1)


@pytest.mark.parametrize("kind", ["mono", "sharded"])
def test_delta_version_guard(kind):
    rng = np.random.default_rng(2)
    st = make_store(kind, rng.integers(0, 20, 100), rng.integers(0, 6, 100),
                    rng.integers(0, 20, 100), 20, 6)
    d = delta_between(st, st.triples()[10:])
    st.apply_delta(d)
    with pytest.raises(DeltaVersionError):
        st.apply_delta(d)                 # store moved; stale delta rejected


def test_delta_apply_is_idempotent_per_side():
    rng = np.random.default_rng(3)
    st = TripleStore(rng.integers(0, 20, 100), rng.integers(0, 6, 100),
                     rng.integers(0, 20, 100), 20, 6)
    present = st.triples()[:1]
    absent = np.array([[19, 5, 19]])
    assert not (rows_set(st) == absent[0]).all(1).any()
    d = TripleDelta(base_version=st.version, add=present, evict=absent)
    before = rows_set(st)
    st.apply_delta(d)                     # add-present + evict-absent: no-op
    assert np.array_equal(rows_set(st), before)


def test_sharded_delta_touches_only_owning_shards():
    rng = np.random.default_rng(4)
    s, p, o = (rng.integers(0, 40, 400), rng.integers(0, 12, 400),
               rng.integers(0, 40, 400))
    st = ShardedTripleStore(s, p, o, 40, 12, num_shards=4)
    pid = 3
    owner = st.shard_of_pred(pid)
    shard_versions = [sh.version for sh in st.shards]
    d = delta_between(st, np.concatenate(
        [st.triples(), np.array([[39, pid, 38]])]))
    st.apply_delta(d)
    changed = [k for k, sh in enumerate(st.shards)
               if sh.version != shard_versions[k]]
    assert changed == [owner]
    # global layout stays consistent with a from-scratch construction
    ref = ShardedTripleStore(st.s, st.p, st.o, 40, 12, num_shards=4)
    assert np.array_equal(st.pred_count, ref.pred_count)
    for q in range(12):
        assert np.array_equal(np.sort(st.p[st.pred_tids(q)]),
                              np.sort(ref.p[ref.pred_tids(q)]))


@pytest.mark.parametrize("kind", ["mono", "sharded"])
@pytest.mark.parametrize("backend", [
    "numpy", pytest.param("jax", marks=pytest.mark.slow)])
def test_query_results_equal_after_in_place_delta(kind, backend,
                                                  small_graph):
    """Engine results on a delta-mutated store == a store freshly built
    from the same triples (indexes, caches, staging all rebuilt)."""
    g = small_graph
    st = (ShardedTripleStore.from_store(g.store, 3) if kind == "sharded"
          else TripleStore(g.store.s, g.store.p, g.store.o,
                           g.store.num_entities, g.store.num_predicates))
    qs = [parse_sparql(t, g.dictionary)
          for t in workload_sparql(g, 6, seed=11)]
    eng = QueryEngine(backend=backend)
    eng.execute_batch(st, qs)             # warm caches on the old version
    d = delta_between(st, st.triples()[st.num_triples // 10:])
    st.apply_delta(d)
    fresh = make_store(kind, st.s, st.p, st.o, st.num_entities,
                       st.num_predicates)
    for res, ref in zip(eng.execute_batch(st, qs),
                        eng.execute_batch(fresh, qs)):
        assert sol_rows(res) == sol_rows(ref)


def test_scan_cache_invalidates_only_touched_shard(small_graph):
    """Version-granular invalidation: a delta to one shard leaves cached
    bound-predicate scans of other shards valid (and re-lifts their ids by
    the store's shifted offsets)."""
    g = small_graph
    st = ShardedTripleStore.from_store(g.store, 4)
    # two bound-predicate patterns owned by different shards
    pids = {}
    for pid in range(st.num_predicates):
        if st.pred_count[pid]:
            pids.setdefault(st.shard_of_pred(pid), pid)
        if len(pids) >= 2:
            break
    assert len(pids) >= 2, "need predicates in two different shards"
    (shard_a, pid_a), (shard_b, pid_b) = list(pids.items())[:2]
    # constant subjects force real candidate scans (free-s/o bound-predicate
    # patterns would take the presorted pred_index join and never scan)
    s_a = int(st.s[st.pred_tids(pid_a)[0]])
    s_b = int(st.s[st.pred_tids(pid_b)[0]])
    q = QueryGraph([TriplePattern(s_a, pid_a, "?y"),
                    TriplePattern(s_b, pid_b, "?z")], [])
    eng = QueryEngine(backend="numpy")
    eng.execute_batch(st, [q])
    # mutate ONLY shard_a (grow it so every later shard's offset shifts)
    add = np.array([[st.num_entities - 1, pid_a, st.num_entities - 2]])
    st.apply_delta(TripleDelta(base_version=st.version, add=add))
    h0, m0 = eng.stats.scan_cache_hits, eng.stats.scan_cache_misses
    res = eng.execute_batch(st, [q])[0]
    # pid_b's scan (untouched shard) hits; pid_a's (touched) re-scans
    assert eng.stats.scan_cache_hits == h0 + 1
    assert eng.stats.scan_cache_misses == m0 + 1
    assert sol_rows(res) == sol_rows(match_bgp(st, q))


# ---------------------------------------------------------------------------
# placement policy: per-shard budgets, tie-breaks, hysteresis
# ---------------------------------------------------------------------------


def test_knapsack_pattern_larger_than_budget_never_selected():
    profs = [PatternProfile(None, frequency=100, size_bytes=500),
             PatternProfile(None, frequency=1, size_bytes=10)]
    assert greedy_knapsack(profs, budget_bytes=100) == [1]
    assert greedy_knapsack(profs, budget_bytes=0) == []


def test_knapsack_per_shard_budget_rejects_hot_shard_overflow():
    profs = [
        # hottest, but all bytes land in shard 0 (over its budget)
        PatternProfile(None, 100, 80, shard_bytes={0: 80}),
        # spread across shards: fits everywhere
        PatternProfile(None, 50, 80, shard_bytes={0: 40, 1: 40}),
        # no shard info: total check only
        PatternProfile(None, 10, 20),
    ]
    assert greedy_knapsack(profs, budget_bytes=1000) == [0, 1, 2]
    chosen = greedy_knapsack(profs, budget_bytes=1000,
                             shard_budgets={0: 60, 1: 60})
    assert chosen == [1, 2]
    # zero budget on one shard blocks everything touching it
    assert greedy_knapsack(profs, budget_bytes=1000,
                           shard_budgets={0: 0, 1: 60}) == [2]


def test_knapsack_frequency_tiebreak_after_decay():
    # equal benefit/cost ratio -> higher absolute frequency wins the slot
    profs = [PatternProfile(None, 10, 100), PatternProfile(None, 100, 1000)]
    assert greedy_knapsack(profs, budget_bytes=1000) == [1]
    dp = DynamicPlacement(budget_bytes=1000)
    q1 = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    q2 = QueryGraph([TriplePattern("?x", 1, "?y")], [])
    p1, p2 = pattern_of(q1), pattern_of(q2)
    dp.set_size(p1, 100), dp.set_size(p2, 1000)
    dp.observe(p1, 10), dp.observe(p2, 100)
    chosen, _, _ = dp.plan()
    for _ in range(5):
        dp.decay_round()                 # decay preserves ratios AND order
    chosen2, _, _ = dp.plan()
    assert chosen == chosen2 == {p2.key}


def test_hysteresis_damps_add_evict_flapping():
    q_a = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    q_b = QueryGraph([TriplePattern("?x", 1, "?y")], [])
    pa, pb = pattern_of(q_a), pattern_of(q_b)
    dp = DynamicPlacement(budget_bytes=100, hysteresis=0.2)
    dp.set_size(pa, 100), dp.set_size(pb, 100)
    dp.observe(pa, 10)
    added, evicted = dp.rebalance()
    assert [p.key for p in added] == [pa.key]
    # challenger 10% hotter: within the 20% hysteresis margin -> no flip
    dp.observe(pb, 11)
    chosen, _, ev = dp.plan()
    assert not ev and chosen == {pa.key}
    # challenger 50% hotter: beats the margin -> swap happens
    dp.observe(pb, 4)
    chosen, add, ev = dp.plan()
    assert chosen == {pb.key} and ev == {pa.key}
    # without hysteresis the 10%-hotter challenger would have flipped
    dp0 = DynamicPlacement(budget_bytes=100)
    dp0.set_size(pa, 100), dp0.set_size(pb, 100)
    dp0.observe(pa, 10), dp0.rebalance()
    dp0.observe(pb, 11)
    assert dp0.plan()[0] == {pb.key}


def test_placement_respects_per_shard_budgets_end_to_end(small_graph):
    g = small_graph
    store = ShardedTripleStore.from_store(g.store, 3)
    params = SystemParams.synthetic(n_users=6, n_edges=2, seed=3)
    per_shard = 60_000
    sys_ = EdgeCloudSystem(store, g.dictionary, params,
                           storage_budgets=150_000,
                           shard_budgets=per_shard)
    sys_.prepare([workload_sparql(g, 4, seed=500 + n) for n in range(6)])
    queries = [(i % 6, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, 8, seed=21))]
    for _ in range(2):
        sys_.run_round_batched(queries, policy="greedy", execute=False)
    sys_.rebalance_all()
    deployed = 0
    for es in sys_.edges:
        assert es.placement.used_bytes() <= es.budget
        for sid, used in es.placement.used_shard_bytes().items():
            assert used <= per_shard, (es.server_id, sid)
        deployed += bool(es.placement.resident)
    assert deployed >= 1


# ---------------------------------------------------------------------------
# incremental rebalance: memo, deltas, bytes
# ---------------------------------------------------------------------------


def build_system(g, kind, backend="numpy", seed=7, budget=150_000):
    store = (ShardedTripleStore.from_store(g.store, 3) if kind == "sharded"
             else g.store)
    params = SystemParams.synthetic(n_users=8, n_edges=3, seed=seed)
    sys_ = EdgeCloudSystem(store, g.dictionary, params,
                           storage_budgets=budget, backend=backend)
    sys_.prepare([workload_sparql(g, 3, seed=100 + n) for n in range(8)])
    return sys_


def drift(g, sys_, seed=77, n=10, rounds=3):
    """Shift the workload so placement wants adds + evicts."""
    queries = [(i % sys_.params.N, parse_sparql(t, g.dictionary))
               for i, t in enumerate(workload_sparql(g, n, seed=seed))]
    for _ in range(rounds):
        sys_.run_round_batched(queries, policy="greedy", execute=False)
    return queries


def test_noop_rebalance_runs_zero_matcher_calls(small_graph, monkeypatch):
    """Regression (ISSUE 4 satellite 1): unchanged patterns cost zero
    matcher calls — the induced-edge-id memo is keyed (cloud version,
    pattern key)."""
    g = small_graph
    sys_ = build_system(g, "mono")
    drift(g, sys_)
    sys_.rebalance_all()                  # measures any new patterns once
    calls = []
    import repro.core.induced as induced_mod
    real = induced_mod.match_bgp
    monkeypatch.setattr(induced_mod, "match_bgp",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    changes = sys_.rebalance_all()        # no new observations since
    assert calls == [], "no-op rebalance must not re-derive subgraphs"
    assert sys_.last_rebalance.matcher_calls == 0
    assert all(a == 0 and e == 0 for a, e in changes.values())
    # ... and a residency CHANGE still only matches genuinely new patterns
    new_q = parse_sparql(
        "SELECT ?a WHERE { ?a <follows> ?b . ?b <follows> ?c . "
        "?c <follows> ?a }", g.dictionary)
    p = pattern_of(new_q)
    for es in sys_.edges:
        es.placement.observe(p, 50.0)
    sys_.rebalance_all()
    assert len(calls) == 1, "only the ONE new pattern hits the matcher"


@pytest.mark.parametrize("kind", ["mono", "sharded"])
def test_delta_and_full_rebalance_agree(small_graph, kind):
    g = small_graph
    sys_d = build_system(g, kind)
    sys_f = build_system(g, kind)
    qs = drift(g, sys_d)
    drift(g, sys_f)
    ch_d = sys_d.rebalance_all(use_deltas=True)
    ch_f = sys_f.rebalance_all(use_deltas=False)
    assert ch_d == ch_f
    rep_d, rep_f = sys_d.last_rebalance, sys_f.last_rebalance
    for es_d, es_f in zip(sys_d.edges, sys_f.edges):
        assert es_d.placement.resident == es_f.placement.resident
        if es_d.store is not None and es_f.store is not None:
            assert np.array_equal(rows_set(es_d.store), rows_set(es_f.store))
            assert np.array_equal(np.sort(es_d.resident_eids),
                                  np.sort(es_f.resident_eids))
    if rep_d.changed:
        modes = {e.mode for e in rep_d.per_edge if e.shipped_bytes}
        assert modes <= {"delta"}
        assert rep_d.shipped_bytes < rep_f.shipped_bytes
    # queries still answer identically to the cloud afterwards
    for (_, q) in qs[:4]:
        p = pattern_of(q)
        want = sol_rows(sys_d.engine.execute(sys_d.cloud.store, q))
        for es in sys_d.edges:
            if es.can_execute(p):
                assert sol_rows(sys_d.engine.execute(es.store, q)) == want


@pytest.mark.parametrize("kind", ["mono", "sharded"])
@pytest.mark.parametrize("use_delta", [True, False])
def test_cloud_mutation_resyncs_edges_without_pattern_changes(kind,
                                                              use_delta):
    """Review regression: a cloud-store delta (live ingest) with an
    UNCHANGED resident pattern set must still refresh edge stores — and
    the diff must not trust edge ids across cloud versions (the cloud id
    space shifts under apply_delta)."""
    rng = np.random.default_rng(8)
    n = 400
    s, p, o = (rng.integers(0, 60, n), rng.integers(0, 8, n),
               rng.integers(0, 60, n))
    cloud = make_store(kind, s, p, o, 60, 8)
    from repro.edge.server import EdgeServer
    es = EdgeServer(0, 10**9, 1e8)
    q = QueryGraph([TriplePattern("?x", 0, "?y")], [])
    pat = pattern_of(q)
    es.placement.observe(pat, 5.0)
    es.measure_pattern(cloud, pat)
    es.deploy(cloud, [pat])
    assert sol_rows(match_bgp(es.store, q)) == sol_rows(match_bgp(cloud, q))
    # live ingest: pred-0 rows appear and one disappears; id space shifts
    d = delta_between(cloud, np.concatenate(
        [cloud.triples()[5:], np.array([[58, 0, 59], [59, 0, 58]])]))
    cloud.apply_delta(d)
    changes = es.rebalance(cloud, use_delta=use_delta)
    assert changes == (0, 0)               # pattern set did not change...
    # ...but the edge was resynced to the new cloud content
    assert sol_rows(match_bgp(es.store, q)) == sol_rows(match_bgp(cloud, q))
    assert es.resident_cloud_version == cloud.version
    # and a further no-op rebalance commits nothing
    v = es.store.version
    es.rebalance(cloud, use_delta=use_delta)
    assert es.store.version == v


def test_cloud_moving_between_compute_and_commit_forces_recompute(
        small_graph):
    """Review regression: plans are bound to the cloud version they were
    computed against — a cloud delta landing between the lock-free compute
    phase and the commit barrier must trigger a recompute, never a commit
    of stale id-space coordinates."""
    g = small_graph
    sys_ = build_system(g, "mono")
    queries = drift(g, sys_)
    fired = {"n": 0}

    def ingest_once():
        fired["n"] += 1
        if fired["n"] == 1:              # mutate the cloud mid-rebalance
            cloud = sys_.cloud.store
            d = delta_between(cloud, np.concatenate(
                [cloud.triples()[3:],
                 np.array([[0, 0, 1], [1, 0, 2]])]))
            cloud.apply_delta(d)

    sys_.rebalancer.pre_commit_hook = ingest_once
    sys_.rebalance_all()
    assert fired["n"] == 2               # first plan discarded, recomputed
    for es in sys_.edges:
        assert es.resident_cloud_version == sys_.cloud.store.version
    q = queries[0][1]
    p = pattern_of(q)
    want = sol_rows(sys_.engine.execute(sys_.cloud.store, q))
    for es in sys_.edges:
        if es.can_execute(p):
            assert sol_rows(sys_.engine.execute(es.store, q)) == want


# ---------------------------------------------------------------------------
# epoch/barrier handshake: parity + feasibility under concurrency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["mono", "sharded"])
@pytest.mark.parametrize("backend", [
    "numpy", pytest.param("jax", marks=pytest.mark.slow)])
def test_overlapped_rebalance_parity(small_graph, kind, backend):
    """Acceptance: a round concurrent with an overlapped rebalance returns
    byte-identical results to sequential rebalance-then-round."""
    g = small_graph
    sys_a = build_system(g, kind, backend=backend)
    sys_b = build_system(g, kind, backend=backend)
    queries = drift(g, sys_a)
    drift(g, sys_b)

    # A: sequential rebalance, then round
    sys_a.rebalance_all()
    rep_a = sys_a.run_round_batched(queries, policy="greedy", observe=False)

    # B: rebalance overlaps the round; its commit races the round's barrier
    release = threading.Event()
    sys_b.rebalancer.pre_commit_hook = lambda: release.wait(10)
    handle = sys_b.rebalance_async()
    round_out = {}

    def run_round():
        round_out["rep"] = sys_b.run_round_batched(
            queries, policy="greedy", observe=False)

    t = threading.Thread(target=run_round)
    t.start()
    release.set()                        # commit and round now race the lock
    t.join(30)
    assert not t.is_alive()
    report = handle.join(30)
    rep_b = round_out["rep"]

    # byte-identical per-query results, whatever the interleaving
    assert ([o.n_matches for o in rep_a.outcomes]
            == [o.n_matches for o in rep_b.outcomes])
    # after the epoch commits, both systems converged to the same residency
    # and the same edge-store bytes
    for es_a, es_b in zip(sys_a.edges, sys_b.edges):
        assert es_a.placement.resident == es_b.placement.resident
        if es_a.store is not None:
            assert np.array_equal(rows_set(es_a.store), rows_set(es_b.store))
    assert report.epoch == sys_b.placement_epoch
    # post-commit round: solution multisets equal to the cloud oracle
    # (byte-identical bindings under a canonical row order)
    for (_, q) in queries[:3]:
        p = pattern_of(q)
        want = sol_rows(sys_b.engine.execute(sys_b.cloud.store, q))
        for es in sys_b.edges:
            if es.can_execute(p):
                assert sol_rows(sys_b.engine.execute(es.store, q)) == want


def test_feasibility_never_stale_under_hammered_rebalance(small_graph):
    """Satellite 2: e_nk is wired to placement epochs — no query is ever
    assigned to an edge lacking its pattern, even with rebalances
    hammering placement between and during rounds."""
    g = small_graph
    sys_ = build_system(g, "sharded")
    queries = drift(g, sys_)
    stop = threading.Event()
    errors = []

    def hammer():
        try:
            while not stop.is_set():
                sys_.rebalance_all()
        except Exception as exc:         # pragma: no cover
            errors.append(exc)

    t = threading.Thread(target=hammer)
    t.start()
    try:
        epoch0 = sys_.placement_epoch
        for i in range(6):
            rep = sys_.run_round_batched(queries, policy="greedy",
                                         observe=True)
            for o in rep.outcomes:
                if o.assigned_to >= 0:
                    assert o.assigned_to in o.executable_edges
    finally:
        stop.set()
        t.join(30)
    assert not errors
    assert sys_.placement_epoch > epoch0   # rebalances actually committed
    # final state still answers correctly
    for (_, q) in queries[:3]:
        p = pattern_of(q)
        want = sol_rows(sys_.engine.execute(sys_.cloud.store, q))
        for es in sys_.edges:
            if es.can_execute(p):
                assert sol_rows(sys_.engine.execute(es.store, q)) == want


def test_endpoint_result_memo_never_stale_under_hammered_deltas(small_graph):
    """Companion to the feasibility hammer (ISSUE 6 satellite 3): an
    endpoint's version-keyed result memo stays correct while a churn thread
    hammers ``apply_delta`` against in-flight ``query_many`` batches.

    The churn is a content-no-op (each delta evicts and re-adds the same
    row), so the data is constant while the version token moves constantly
    — any batch caching results under its dispatch-time version after a
    mid-batch move would be flagged by ``_run``'s re-validation; here we
    assert the observable contract: every answer equals the static
    reference, nothing errors, and post-churn queries still cache sanely.
    """
    from repro.sparql.endpoint import SparqlEndpoint
    g = small_graph
    ep = SparqlEndpoint(g.store, g.dictionary)
    texts = workload_sparql(g, 6, seed=9)
    ref = [sol_rows(t) for t in
           SparqlEndpoint(g.store, g.dictionary).query_many(texts)]
    stop = threading.Event()
    errors = []

    def churn():
        try:
            while not stop.is_set():
                row = g.store.triples()[:1]
                g.store.apply_delta(TripleDelta(
                    base_version=g.store.version, add=row, evict=row))
        except Exception as exc:          # pragma: no cover - fail path
            errors.append(exc)

    def client(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(40):
                idx = [int(rng.integers(len(texts))) for _ in range(3)]
                tables = ep.query_many([texts[i] for i in idx])
                for i, t in zip(idx, tables):
                    assert sol_rows(t) == ref[i], texts[i]
        except Exception as exc:          # pragma: no cover - fail path
            errors.append(exc)

    churner = threading.Thread(target=churn)
    clients = [threading.Thread(target=client, args=(s,)) for s in range(4)]
    churner.start()
    try:
        for c in clients:
            c.start()
        for c in clients:
            c.join(60)
    finally:
        stop.set()
        churner.join(30)
    assert not errors, errors[:1]
    # post-churn: a quiet batch caches under the now-stable version and
    # still answers from the memo correctly
    tables = ep.query_many(texts)
    for want, t in zip(ref, tables):
        assert sol_rows(t) == want
    v = g.store.version
    assert any(k == (texts[0], v) for k in ep._results)
    assert sol_rows(ep.query(texts[0])) == ref[0]


def test_serving_pool_republish_is_atomic():
    from repro.runtime.serving import OffloadServingPool, Replica
    pool = OffloadServingPool(
        replicas=[Replica(0, classes={0}, cycles_per_s=1e8, link_bps=1e7,
                          runner=lambda ps: ["edge"] * len(ps))],
        cloud_runner=lambda ps: ["cloud"] * len(ps))
    reqs = [{"class_id": 1, "cycles": 1e6, "result_bits": 8e3,
             "payload": i} for i in range(3)]
    out = pool.admit(reqs, policy="edge_first")
    assert list(out.assignments) == [-1, -1, -1]     # class 1 not served
    epoch = pool.republish(0, {0, 1})
    assert epoch == 1
    out = pool.admit(reqs, policy="edge_first")
    assert list(out.assignments) == [0, 0, 0]        # now feasible at edge
    with pytest.raises(KeyError):
        pool.republish(99, {0})
