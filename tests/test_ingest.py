"""Live-ingest write path (PR 9): SPARQL UPDATE grammar + compilation,
the cloud-side single ingest path (id-stable shard routing, memo /
certificate invalidation only for touched patterns, version-consistent
edge propagation), and the oracle-equivalence hammer — concurrent
INSERT/DELETE traffic against query rounds on {numpy, jax} x {mono,
sharded}, where every read must observe a fully-committed placement
epoch and post-quiesce results must match a rebuilt-from-scratch store
bit-for-bit."""

import threading
import time

import numpy as np
import pytest

from repro.core.cost import SystemParams
from repro.core.pattern import pattern_of
from repro.edge.system import EdgeCloudSystem
from repro.rdf.deltas import TripleDelta
from repro.rdf.dictionary import Dictionary
from repro.rdf.generator import generate_watdiv_like, workload_sparql
from repro.rdf.graph import TripleStore
from repro.rdf.sharding import ShardedTripleStore
from repro.sparql.endpoint import SparqlEndpoint
from repro.sparql.query import (ParseError, is_update_text, parse_sparql,
                                parse_update)
from repro.sparql.update import (compile_update, ground_delta,
                                 where_evict_rows)

from test_engine import BACKENDS, sol_rows

STORE_KINDS = ["mono", "sharded"]

# per-edge resident leaves (same shape as the partial-eval suite): the
# hammer's workload routes through edges AND cloud, so ingest must keep
# every replica version-consistent for results to stay oracle-equal
LEAVES = {
    0: ["SELECT ?x ?p WHERE { ?x <likes> ?p }"],
    1: ["SELECT ?p ?gn WHERE { ?p <hasGenre> ?gn }",
        "SELECT ?x ?y WHERE { ?x <follows> ?y }"],
    2: ["SELECT ?x ?c WHERE { ?x <country> ?c }"],
}


@pytest.fixture(scope="module")
def graph():
    return generate_watdiv_like(scale=0.5, seed=42)


def fresh_store(g, kind):
    """Copy the fixture's triples into a NEW store: ingest tests mutate."""
    base = TripleStore(np.asarray(g.store.s).copy(),
                       np.asarray(g.store.p).copy(),
                       np.asarray(g.store.o).copy(),
                       g.dictionary.num_entities,
                       g.dictionary.num_predicates)
    if kind == "sharded":
        return ShardedTripleStore.from_store(base, num_shards=4)
    return base


def make_system(g, store, backend="numpy"):
    K, N = 3, 4
    params = SystemParams(
        F=np.full(K, 1.0e9),
        r_edge=np.full((N, K), 75e6),
        r_cloud=np.full(N, 5e6),
        assoc=np.ones((N, K), dtype=bool),
        r_backhaul=np.full(K, 1e9),
        F_cloud=0.05e9,
    )
    sys_ = EdgeCloudSystem(store, g.dictionary, params,
                           storage_budgets=10_000_000, backend=backend)
    for k, texts in LEAVES.items():
        sys_.edges[k].deploy(store, [pattern_of(parse_sparql(
            t, g.dictionary)) for t in texts])
    return sys_


# -- grammar / compilation ----------------------------------------------------
def test_update_text_routing():
    assert is_update_text("INSERT DATA { <a> <b> <c> }")
    assert is_update_text("  delete data { <a> <b> <c> }")
    assert is_update_text("PREFIX ex: <http://e/> "
                          "DELETE WHERE { ex:a ?p ?o }")
    assert not is_update_text("SELECT ?x WHERE { ?x <likes> ?y }")
    assert not is_update_text("PREFIX ex: <http://e/> "
                              "ASK { ex:a ex:b ex:c }")


def test_update_parser_rejections():
    d = Dictionary()
    with pytest.raises(ParseError):        # variables in ground data
        parse_update("INSERT DATA { ?x <likes> <a> }", d)
    with pytest.raises(ParseError):        # not an update form
        parse_update("SELECT ?x WHERE { ?x <likes> ?y }", d)
    with pytest.raises(ParseError):        # DELETE WHERE needs a BGP
        parse_update("DELETE WHERE { }", d)
    with pytest.raises(ParseError):        # unterminated block
        parse_update("INSERT DATA { <a> <b> <c>", d)
    # DELETE WHERE accepts variables (it is a template, not ground data)
    parsed = parse_update("DELETE WHERE { <a> ?p ?o }", d)
    assert parsed.kind == "delete_where" and len(parsed.triples) == 1


def test_compile_update_against_fresh_dictionary():
    d = Dictionary()
    cu = compile_update(parse_update(
        "INSERT DATA { <a> <likes> <b> . <a> <likes> <b> }", d), d)
    assert cu.kind == "insert_data"
    assert len(cu.add) == 1                # ground duplicates collapse
    assert cu.new_terms == 3               # a, likes, b minted once
    # deleting terms the dictionary has never seen is a counted no-op
    cu2 = compile_update(parse_update(
        "DELETE DATA { <zz> <likes> <b> }", d), d)
    assert cu2.is_noop and cu2.dropped_rows == 1


# -- satellite (c): version bump + memo invalidation --------------------------
def test_insert_new_terms_bumps_version_and_invalidates_memos():
    g = generate_watdiv_like(scale=0.2, seed=9)
    ep = SparqlEndpoint(g.store, g.dictionary)
    q = "SELECT ?x ?p WHERE { ?x <likes> ?p }"
    v0 = g.dictionary.version
    n0 = ep.query(q).num_matches
    h0 = ep.memo_hits
    assert ep.query(q).num_matches == n0
    assert ep.memo_hits == h0 + 1          # result LRU serves the repeat
    ack = ep.update("INSERT DATA { <fresh_u> <likes> <fresh_p> }")
    assert ack["new_terms"] == 2 and ack["inserted"] == 1
    assert g.dictionary.version > v0       # new terms bump the version
    # plan memo keys on (text, dictionary.version): the stale plan (with
    # the old id space baked in) can no longer be served
    ep.parse(q)
    assert (q, g.dictionary.version) in ep._plans
    # result LRU keys on (text, store.version): the pre-insert cached
    # table must not be served post-insert
    m0 = ep.memo_misses
    t = ep.query(q)
    assert ep.memo_misses == m0 + 1
    assert t.num_matches == n0 + 1


# -- raw delta ingest ---------------------------------------------------------
@pytest.mark.parametrize("kind", STORE_KINDS)
def test_apply_delta_raw_rows_and_idempotency(graph, kind):
    g = graph
    store = fresh_store(g, kind)
    sys_ = make_system(g, store)
    row = np.asarray(store.triples())[:1].copy()
    v0 = store.version
    rep = sys_.apply_delta(add=row)        # already present: no-op
    assert rep.is_noop and store.version == v0
    rep = sys_.apply_delta(evict=row)
    assert rep.n_evict == 1 and store.version != v0
    rep = sys_.apply_delta(add=row)
    assert rep.n_add == 1
    for es in sys_.edges:
        if es.store is not None:
            assert es.resident_cloud_version == store.version
    assert sol_rows(sys_.engine.execute(
        store, parse_sparql("SELECT ?x ?p WHERE { ?x <likes> ?p }",
                            g.dictionary))) \
        == sol_rows(sys_.engine.execute(
            fresh_store(g, "mono"),
            parse_sparql("SELECT ?x ?p WHERE { ?x <likes> ?p }",
                         g.dictionary)))


# -- the oracle-equivalence hammer --------------------------------------------
def _update_stream(tag):
    """Scripted mixed traffic: minted-term inserts, ground deletes of both
    present and never-present rows, a re-insert (idempotent add), and a
    variable-predicate DELETE WHERE (full memo invalidation path)."""
    out = []
    for i in range(6):
        out.append(f"INSERT DATA {{ <{tag}_u{i}> <likes> <{tag}_p{i}> . "
                   f"<{tag}_u{i}> <country> <{tag}_c{i % 2}> }}")
    out.append(f"DELETE DATA {{ <{tag}_u1> <likes> <{tag}_p1> }}")
    out.append(f"DELETE DATA {{ <{tag}_u1> <likes> <{tag}_p1> }}")  # gone
    out.append(f"INSERT DATA {{ <{tag}_u1> <likes> <{tag}_p1> }}")
    out.append(f"DELETE WHERE {{ <{tag}_u3> ?p ?o }}")
    out.append(f"DELETE DATA {{ <{tag}_never> <likes> <{tag}_p0> }}")
    return out


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", STORE_KINDS)
def test_ingest_oracle_equivalence_hammer(graph, backend, kind):
    g = graph
    store = fresh_store(g, kind)
    sys_ = make_system(g, store, backend=backend)
    initial = np.asarray(store.triples()).copy()
    updates = _update_stream(f"w_{kind}_{backend}")
    errors: list[BaseException] = []

    def writer():
        try:
            for i, text in enumerate(updates):
                sys_.apply_update(text)
                if i == len(updates) // 2:
                    # pipelined placement maintenance mid-stream: epochs
                    # commit between the writes and the rounds below
                    sys_.rebalance_pipeline(epochs=2)
                time.sleep(0.002)
        except BaseException as err:       # re-raised by the main thread
            errors.append(err)

    th = threading.Thread(target=writer, name="ingest-writer")
    th.start()
    texts = workload_sparql(g, 8, seed=3)
    rounds = 0
    deadline = time.monotonic() + 60.0
    while (th.is_alive() or rounds < 3) and time.monotonic() < deadline:
        queries = [(i % sys_.params.N, parse_sparql(t, g.dictionary))
                   for i, t in enumerate(texts)]
        # the placement lock is reentrant: holding it here makes the round
        # + the cloud oracle + the consistency probes ONE atomic read —
        # any concurrent write/rebalance commits strictly before or after
        with sys_._placement_lock:
            e0, v0 = sys_.placement_epoch, store.version
            rep = sys_.run_round_batched(queries, policy="greedy",
                                         execute=True,
                                         collect_results=True)
            oracle = [sys_.engine.execute(store, q) for _, q in queries]
            # a read never observes a half-applied placement: the epoch
            # and cloud version are stable across the round, and every
            # populated edge replica is at the cloud's exact version
            assert sys_.placement_epoch == e0
            assert store.version == v0
            for es in sys_.edges:
                if es.store is not None:
                    assert es.resident_cloud_version == store.version
        for res, want in zip(rep.results, oracle):
            assert sol_rows(res) == sol_rows(want)
        rounds += 1
    th.join(30.0)
    assert not th.is_alive(), "writer wedged"
    assert not errors, errors
    assert rounds >= 3

    # post-quiesce: rebuild from scratch (initial rows + the same update
    # stream replayed against the now-final dictionary) and compare the
    # triple sets and query answers bit-for-bit
    rebuilt = TripleStore(initial[:, 0].copy(), initial[:, 1].copy(),
                          initial[:, 2].copy(),
                          g.dictionary.num_entities,
                          g.dictionary.num_predicates)
    for text in updates:
        cu = compile_update(parse_update(text, g.dictionary), g.dictionary)
        if cu.kind == "delete_where":
            delta = TripleDelta(base_version=rebuilt.version,
                                evict=where_evict_rows(cu, rebuilt))
        else:
            delta = ground_delta(cu, rebuilt)
        if not delta.is_noop:
            rebuilt.apply_delta(delta)
    got = np.unique(np.asarray(store.triples()), axis=0)
    want = np.unique(np.asarray(rebuilt.triples()), axis=0)
    assert np.array_equal(got, want)
    for _, q in [(0, parse_sparql(t, g.dictionary)) for t in texts]:
        assert sol_rows(sys_.engine.execute(store, q)) \
            == sol_rows(sys_.engine.execute(rebuilt, q))


# -- window-level write coalescing (admission follow-on (b)) ------------------


@pytest.mark.parametrize("kind", STORE_KINDS)
def test_update_many_coalesces_to_one_commit(graph, kind):
    g = graph
    store = fresh_store(g, kind)
    sys_ = make_system(g, store)
    ep = SparqlEndpoint.from_system(sys_)
    epoch0 = sys_.placement_epoch
    texts = [f"INSERT DATA {{ <coalU{i}> <likes> <Product{i % 3}> }}"
             for i in range(6)]
    outs = ep.update_many(texts)
    assert all(isinstance(o, dict) for o in outs)
    assert all(o["inserted"] == 1 and o["coalesced"] == 6 for o in outs)
    # ONE cloud commit + ONE propagation round for the whole group: the
    # ingest path ran once, so every ack carries the same placement epoch
    assert ep.write_commits == 1
    assert len({o["placement_epoch"] for o in outs}) == 1
    assert sys_.placement_epoch <= epoch0 + 1
    # every inserted row is queryable
    for i in range(6):
        assert ep.query(f"SELECT ?p WHERE {{ <coalU{i}> <likes> ?p }}"
                        ).num_matches == 1


@pytest.mark.parametrize("kind", STORE_KINDS)
def test_update_many_order_isolation_and_parity(graph, kind):
    g = graph
    store = fresh_store(g, kind)
    ep = SparqlEndpoint(store, g.dictionary)
    texts = [
        "INSERT DATA { <wA> <likes> <Product0> . <wB> <likes> <Product1> }",
        "DELETE DATA { <wA> <likes> <Product0> }",     # cancels half of #0
        "INSERT DATA { <wA> <likes> <Product0> }",     # re-adds it
        "NOT AN UPDATE {",                             # isolated failure
        "DELETE WHERE { <wB> ?p ?o }",                 # flushes, runs solo
        "DELETE DATA { <wNever> <likes> <Product0> }",  # unknown: no-op
    ]
    outs = ep.update_many(texts)
    assert outs[0]["inserted"] == 2
    assert outs[1]["deleted"] == 1      # the row was effectively present
    assert outs[2]["inserted"] == 1     # and absent again at position 2
    assert isinstance(outs[3], ParseError)
    assert outs[4]["deleted"] == 1      # sees the flushed group's <wB> row
    assert outs[5]["deleted"] == 0 and outs[5]["dropped_rows"] == 1
    # sequential replay on a fresh copy lands on the same content
    seq_store = fresh_store(g, kind)
    ep_seq = SparqlEndpoint(seq_store, g.dictionary)
    for t in texts:
        try:
            ep_seq.update(t)
        except ParseError:
            pass
    assert np.array_equal(np.unique(np.asarray(store.triples()), axis=0),
                          np.unique(np.asarray(seq_store.triples()), axis=0))


def test_admission_queue_coalesce_writes_stats(graph):
    from repro.runtime.admission import AdmissionQueue
    g = graph
    store = fresh_store(g, "mono")
    ep = SparqlEndpoint(store, g.dictionary)
    n = 5
    texts = [f"INSERT DATA {{ <qU{i}> <follows> <User0> }}"
             for i in range(n)]
    with AdmissionQueue(ep, window_s=0.2, max_batch=64,
                        coalesce_writes=True) as q:
        tickets = [q.submit(t) for t in texts]
        acks = [t.result(10.0) for t in tickets]
    assert all(a["inserted"] == 1 for a in acks)
    # the window's writes took one commit; the rest were amortized away
    assert q.stats.updates_served == n
    assert q.stats.write_commits == 1
    assert q.stats.writes_coalesced == n - 1
    assert q.stats.recent[-1].write_commits == 1
    sd = q.stats.as_dict()
    assert sd["writes_coalesced"] == n - 1
    # reads in the same window still see the pre-window store: covered by
    # the existing serving tests; here just confirm the rows landed
    for i in range(n):
        assert ep.query(f"SELECT ?x WHERE {{ <qU{i}> <follows> ?x }}"
                        ).num_matches == 1


def test_admission_queue_coalesce_commit_failure_rejects_window(graph):
    from repro.runtime.admission import AdmissionQueue
    g = graph
    store = fresh_store(g, "mono")
    ep = SparqlEndpoint(store, g.dictionary)

    def boom(texts):
        raise RuntimeError("fold bug")

    ep.update_many = boom
    with AdmissionQueue(ep, window_s=0.05, max_batch=64,
                        coalesce_writes=True) as q:
        tickets = [q.submit(f"INSERT DATA {{ <qF{i}> <follows> <User0> }}")
                   for i in range(3)]
        # an exception escaping the coalesced commit must reject every
        # ticket of the window, not strand them unresolved forever
        for t in tickets:
            with pytest.raises(RuntimeError, match="fold bug"):
                t.result(5.0)
    assert q.stats.failed == 3
    assert q.stats.updates_served == 0
