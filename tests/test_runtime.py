"""Runtime substrate: optimizer, checkpointing, fault tolerance, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.optim.compression import (dequantize_int8, ef_compress,
                                     ef_compress_tree, ef_decompress_tree,
                                     init_residuals, quantize_int8)
from repro.runtime.checkpoint import (latest_step, restore_checkpoint,
                                      save_checkpoint)
from repro.runtime.fault_tolerance import (StragglerMonitor, plan_mesh,
                                           simulate_failure, with_retries)
from repro.runtime.serving import OffloadServingPool, Replica
from repro.runtime.train_loop import (TrainLoopConfig, make_train_step,
                                      train)


# -- optimizer ---------------------------------------------------------------

def quad_loss(params, batch):
    err = params["w"] - batch["target"]
    return jnp.sum(err * err), {"dummy": jnp.zeros(())}


def test_adamw_converges():
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.zeros(8, jnp.float32)}
    target = jnp.arange(8, dtype=jnp.float32) / 8.0
    st = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: quad_loss(p, {"target": target})[0])(params)
        params, st, info = adamw_update(cfg, g, st, params)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      end_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert np.isclose(float(lr_at(cfg, jnp.asarray(10))), 1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) <= 0.11
    assert float(lr_at(cfg, jnp.asarray(55))) < 1.0


def test_adamw_bf16_params_fp32_moments():
    cfg = AdamWConfig(peak_lr=1e-2)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    st = adamw_init(params)
    assert st["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, st2, _ = adamw_update(cfg, g, st, params)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2["step"] == 1


# -- compression --------------------------------------------------------------

def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, 1000), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """With EF, the *running sum* of compressed grads tracks the true sum."""
    rng = np.random.default_rng(1)
    res = jnp.zeros(64, jnp.float32)
    true_sum = np.zeros(64)
    comp_sum = np.zeros(64)
    for i in range(50):
        g = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
        q, s, res = ef_compress(g, res)
        comp_sum += np.asarray(dequantize_int8(q, s))
        true_sum += np.asarray(g)
    # residual bounds the drift
    drift = np.abs(comp_sum + np.asarray(res) - true_sum).max()
    assert drift < 1e-3


def test_ef_tree_roundtrip():
    params = {"a": jnp.ones(8), "b": {"c": jnp.ones((2, 2))}}
    res = init_residuals(params)
    grads = jax.tree.map(lambda p: p * 0.37, params)
    q, s, res2 = ef_compress_tree(grads, res)
    deq = ef_decompress_tree(q, s)
    err = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), grads, deq)
    assert max(jax.tree.leaves(err)) < 0.01


# -- checkpointing -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7, jnp.int32)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, state)
    save_checkpoint(d, 9, jax.tree.map(lambda x: x + 1, state))
    assert latest_step(d) == 9
    step, restored = restore_checkpoint(d, state)
    assert step == 9
    assert np.allclose(restored["params"]["w"],
                       np.asarray(state["params"]["w"]) + 1)


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"x": jnp.zeros(2)}
    for s in range(6):
        save_checkpoint(d, s, state, keep_last=2)
    kept = sorted(p for p in os.listdir(d) if p.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"w": jnp.zeros((3, 3))})


# -- fault tolerance -----------------------------------------------------------

def test_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, n_retries=3)() == "ok"
    assert calls["n"] == 3
    with pytest.raises(ZeroDivisionError):
        with_retries(lambda: 1 / 0, n_retries=1)()


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0)
    flagged = [m.observe(i, 0.1) for i in range(10)]
    assert not any(flagged)
    assert m.observe(10, 1.0)       # 10x the EWMA
    assert m.flagged_steps == [10]
    assert not m.observe(11, 0.1)   # EWMA not poisoned


def test_plan_mesh_elastic():
    assert plan_mesh(512, 16, pod_axis=2) == (2, 16, 16)
    assert plan_mesh(256, 16) == (16, 16)
    # lose a pod: 256 devices left, single-pod layout
    assert plan_mesh(256, 16, pod_axis=1) == (16, 16)
    # lose 3 rows: 208 devices -> 13 data rows
    assert plan_mesh(208, 16) == (13, 16)
    with pytest.raises(ValueError):
        plan_mesh(8, 16)
    devs = list(range(512))
    assert len(simulate_failure(devs, 256)) == 256


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one sharding layout, restore under another (1-device CPU:
    layouts differ logically; correctness = values survive)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ckpt")
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 1, state)
    from repro.launch.mesh import make_compat_mesh
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, restored = restore_checkpoint(d, state, shardings=sh)
    assert np.allclose(restored["w"], np.asarray(state["w"]))
    assert restored["w"].sharding == sh["w"]


# -- train loop -----------------------------------------------------------------

def batches(target):
    while True:
        yield {"target": target}


def test_train_loop_runs_and_checkpoints(tmp_path):
    target = jnp.arange(8, dtype=jnp.float32)
    params = {"w": jnp.zeros(8, jnp.float32)}
    loop = TrainLoopConfig(total_steps=30, log_every=10, ckpt_every=10,
                           ckpt_dir=str(tmp_path / "ck"))
    opt = AdamWConfig(peak_lr=0.2, warmup_steps=2, total_steps=30,
                      weight_decay=0.0)
    res = train(quad_loss, params, batches(target), opt, loop,
                log=lambda *a: None)
    assert latest_step(str(tmp_path / "ck")) == 30
    l0 = float(quad_loss(params, {"target": target})[0])
    l1 = float(quad_loss(res.params, {"target": target})[0])
    assert l1 < l0 * 0.5

    # resume continues from the checkpoint
    res2 = train(quad_loss, params, batches(target), opt,
                 TrainLoopConfig(total_steps=35, ckpt_every=10,
                                 ckpt_dir=str(tmp_path / "ck")),
                 log=lambda *a: None)
    assert res2.resumed_from == 30


def test_microbatch_accumulation_matches_large_batch():
    opt = AdamWConfig(peak_lr=0.1, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.zeros(4, jnp.float32)}
    st = adamw_init(params)
    big = {"target": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}

    def loss_mean(p, b):
        err = p["w"][None, :] - b["target"]
        return jnp.mean(jnp.sum(err * err, -1)), {}

    step1 = make_train_step(loss_mean, opt, microbatches=1)
    p1, _, m1 = jax.jit(step1)(params, st, big)

    def loss_micro(p, b):
        err = p["w"] - b["target"]
        return jnp.sum(err * err), {}

    step2 = make_train_step(loss_micro, opt, microbatches=2)
    micro = {"target": big["target"][:, None, :][:, 0, :]}  # [2, 4]
    p2, _, m2 = jax.jit(step2)(params, st, micro)
    assert np.allclose(p1["w"], p2["w"], atol=1e-6)


# -- offload serving --------------------------------------------------------------

def test_offload_serving_pool():
    replicas = [
        Replica(0, classes={0, 1}, cycles_per_s=2e8, link_bps=75e6,
                runner=lambda xs: [("edge0", x) for x in xs]),
        Replica(1, classes={1, 2}, cycles_per_s=2e8, link_bps=75e6,
                runner=lambda xs: [("edge1", x) for x in xs]),
    ]
    pool = OffloadServingPool(replicas,
                              cloud_runner=lambda xs: [("cloud", x)
                                                       for x in xs])
    rng = np.random.default_rng(0)
    reqs = [{"class_id": int(rng.integers(4)),
             "cycles": float(rng.uniform(1e6, 1e8)),
             "result_bits": float(rng.uniform(1e5, 1e7)),
             "payload": i} for i in range(12)]
    out = pool.admit(reqs, policy="bnb")
    assert len(out.responses) == 12
    for i, (where, payload) in enumerate(out.responses):
        assert payload == i
        j = out.assignments[i]
        if j >= 0:
            assert reqs[i]["class_id"] in replicas[j].classes
            assert where == f"edge{j}"
        else:
            assert where == "cloud"
    # class 3 requests can only go to the cloud
    for i, r in enumerate(reqs):
        if r["class_id"] == 3:
            assert out.assignments[i] == -1


def test_checkpoint_bf16_roundtrip(tmp_path):
    """bf16 params survive npz (stored as raw bits; caught by train_lm)."""
    d = str(tmp_path / "ckpt")
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 8.0,
             "m": jnp.ones(4, jnp.float32)}
    save_checkpoint(d, 1, state)
    step, restored = restore_checkpoint(d, state)
    assert restored["w"].dtype == jnp.bfloat16
    assert np.allclose(np.asarray(restored["w"], np.float32),
                       np.asarray(state["w"], np.float32))
